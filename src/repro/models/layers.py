"""Shared neural-net layers: norms, rotary embeddings, MLPs, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * scale).astype(dt)


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2) / hd))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    # explicit broadcast (no singleton head dim): keeps SPMD shardings of the
    # head axis intact instead of forcing a full rematerialization
    cos = jnp.broadcast_to(jnp.cos(ang)[..., None, :], x1.shape)
    sin = jnp.broadcast_to(jnp.sin(ang)[..., None, :], x1.shape)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 1e4,
                sections=(0.25, 0.375, 0.375)):
    """M-RoPE (Qwen2-VL): rotary frequency channels split into temporal /
    height / width sections, each driven by its own position id.

    x: (B, S, H, hd); positions3: (B, S, 3)."""
    hd = x.shape[-1]
    half = hd // 2
    bounds = np.cumsum([int(half * s) for s in sections])
    bounds[-1] = half
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)     # (half,)
    sec = np.zeros(half, np.int32)
    sec[bounds[0]:bounds[1]] = 1
    sec[bounds[1]:] = 2
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                          # (B, S, 3)
        jnp.broadcast_to(jnp.asarray(sec)[None, None, :],
                         positions3.shape[:2] + (half,)), axis=-1)  # (B,S,half)
    ang = pos * freqs                                            # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def softmax_cross_entropy(logits, labels):
    """logits: (..., V) fp32-accumulated; labels: int (...,)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold

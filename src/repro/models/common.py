"""Model configuration shared by all assigned architectures, plus the
jax-version shard_map compatibility wrapper."""

from __future__ import annotations

from dataclasses import dataclass, replace


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions: newer jax exposes it at the
    top level with ``check_vma``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map`` with the equivalent knob named
    ``check_rep``.  All repo callsites go through this wrapper."""
    import jax
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as esm
    return esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def axis_size(axis_name: str) -> int:
    """``jax.lax.axis_size`` across jax versions: 0.4.x lacks it, but
    ``psum(1, axis)`` is statically evaluated to the (concrete) mesh axis
    size inside shard_map, which is exactly the value callers reshape by."""
    import jax
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | mla_moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0

    # MLA (DeepSeek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # SSM (Mamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1       # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)
    ssm_heads: int = 0         # mamba2 heads (d_inner // head dim of 64)

    # hybrid (zamba2): one weight-shared attention block applied every k layers
    attn_every: int = 0

    # flags
    qkv_bias: bool = False
    qk_norm: bool = False
    mrope: bool = False        # M-RoPE (qwen2-vl): 3-section rotary
    causal: bool = True        # False -> encoder-only (hubert)
    embedding_inputs: bool = False  # modality stub: inputs are embeddings
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # distribution / perf knobs (overridable per run / by GEVO-Shard)
    remat: str = "none"        # none | full  — activation checkpoint per layer
    moe_mode: str = "dense"    # dense | ep_a2a  (decode always uses gather)
    expert_shards: int = 1     # pad expert dim so it divides this (EP width)
    attn_impl: str = "naive"   # naive | blockwise (flash-style, O(S) memory)
    attn_block: int = 512      # q/kv block for blockwise attention
    loss_chunk: int = 0        # seq-chunked xent head (0 = full logits)
    fsdp: bool = True          # ZeRO-3 weight sharding over the DP axes
    ssm_impl: str = "ssd"      # ssd | naive — mamba2 scan formulation
    gnorm_vdot: bool = False   # True reproduces the vdot grad-norm bug (A/B)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers), for 6ND math."""
        d, v = self.d_model, self.vocab
        emb = v * d * 2  # in + out embedding (untied)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encoder", "mla_moe"):
            if self.mla:
                q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.qk_rope_dim)
                kv = d * (self.kv_lora_rank + self.qk_rope_dim) + \
                    self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim)
                o = self.n_heads * self.v_head_dim * d
                attn = q + kv + o
            else:
                attn = d * self.hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * self.hd * d
            if self.n_experts:
                ff = 3 * d * self.moe_d_ff * (self.n_experts
                                              + self.n_shared_experts) \
                    + d * self.n_experts
            else:
                ff = 3 * d * self.d_ff
            per_layer = attn + ff
        elif self.family in ("ssm", "hybrid"):
            di, n = self.d_inner, self.ssm_state
            # in_proj (x,z), conv, dt/B/C projections, out_proj
            per_layer = d * di * 2 + di * self.ssm_conv + di * (2 * n + 2) \
                + di * d
        n_param = emb + self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            # ONE weight-shared attention + MLP block
            shared = 4 * d * self.n_heads * self.hd + 3 * d * self.d_ff
            n_param += shared
        return int(n_param)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_expert = 3 * d * self.moe_d_ff * self.n_experts * self.n_layers
        active_expert = 3 * d * self.moe_d_ff * self.top_k * self.n_layers
        return int(full - all_expert + active_expert)

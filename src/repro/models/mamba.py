"""Mamba selective-state-space blocks (mamba1: falcon-mamba; mamba2: zamba2).

Training/prefill uses a **chunked associative scan**: the sequence is split
into chunks; within a chunk the recurrence h_t = a_t * h_{t-1} + b_t runs as a
parallel ``lax.associative_scan``, and chunk-boundary states are carried by an
outer ``lax.scan``.  This bounds the materialized (B, Q, C, N) state tensor to
one chunk — the same blocking the Mamba CUDA kernel uses, re-expressed for
TPU/XLA (see kernels/mamba_scan for the Pallas version of the inner loop).

Decode keeps (conv_state, ssm_state) and is a single fused update per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .common import ModelConfig
from .layers import dense_init, rms_norm


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    ks = jax.random.split(key, 8)
    dt_rank = max(1, -(-d // 16))
    p = {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d), dtype=dtype),
        "D": jnp.ones((di,), jnp.float32),
    }
    if cfg.ssm_version == 1:
        p.update({
            "x_proj": dense_init(ks[3], (di, dt_rank + 2 * n), dtype=dtype),
            "dt_proj": dense_init(ks[4], (dt_rank, di), dtype=dtype),
            "dt_bias": jnp.full((di,), np.log(np.expm1(0.01)), jnp.float32),
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        })
    else:  # mamba2 (SSD): scalar decay per head; B,C shared across head dim
        H = cfg.ssm_heads or di // 64
        p.update({
            "bc_proj": dense_init(ks[3], (d, 2 * n), dtype=dtype),
            "dt_w": dense_init(ks[4], (d, H), dtype=dtype),
            "dt_bias": jnp.full((H,), np.log(np.expm1(0.01)), jnp.float32),
            "A_log": jnp.zeros((H,), jnp.float32),
            "norm_scale": jnp.ones((di,), dtype),
        })
    return p


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def _causal_conv(x, w, b):
    """Depthwise causal conv over time.  x: (B, L, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        pad, w[:, None, :], (1,), "VALID",
        dimension_numbers=("NLC", "LIO", "NLC"),
        feature_group_count=x.shape[-1])
    return out + b


def _scan_chunked(a, b, h0, chunk: int):
    """Run h_t = a_t * h_{t-1} + b_t over axis 1 with chunked associative scan.

    a, b: (B, L, ...) with identical trailing dims; h0: (B, ...).
    Returns (h at every t: (B, L, ...), final h)."""
    B, L = a.shape[:2]
    chunk = min(chunk, L)
    while L % chunk:  # fall back to the largest divisor <= requested chunk
        chunk -= 1
    nc = L // chunk
    a_c = a.reshape((B, nc, chunk) + a.shape[2:]).swapaxes(0, 1)
    b_c = b.reshape((B, nc, chunk) + b.shape[2:]).swapaxes(0, 1)

    def combine(x, y):
        (ax, bx), (ay, by) = x, y
        return ax * ay, ay * bx + by

    def outer(h, ab):
        a_q, b_q = ab                                  # (B, Q, ...)
        pa, pb = lax.associative_scan(combine, (a_q, b_q), axis=1)
        hs = pa * h[:, None] + pb                       # states at each t
        return hs[:, -1], hs

    h_final, hs = lax.scan(outer, h0, (a_c, b_c))
    hs = hs.swapaxes(0, 1).reshape((B, L) + a.shape[2:])
    return hs, h_final


# --------------------------------------------------------------------------
# mamba1 (falcon-mamba)
# --------------------------------------------------------------------------

def mamba1_seq(p, cfg: ModelConfig, x, h0=None, chunk: int = 128):
    """Full-sequence mamba1.  x: (B, L, d) -> (y, (conv_tail, h_final)).

    The decay/drive tensors exp(dt*A) and dt*x*B are computed PER CHUNK
    inside the chunk scan (``cfg.ssm_impl == "naive"`` materializes them for
    the full L first — a (B, L, d_inner, n) tensor, 22 TB/device on the
    falcon-mamba train cell; see EXPERIMENTS.md §Perf).  Same blocking as
    the Pallas mamba_scan kernel, which computes them in-kernel."""
    B, L, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_tail = xi[:, -(cfg.ssm_conv - 1):, :]
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))

    proj = jnp.einsum("blc,ce->ble", xi, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("blr,rc->blc", proj[..., :dt_rank], p["dt_proj"])
        + p["dt_bias"]).astype(jnp.float32)                    # (B, L, di)
    Bv = proj[..., dt_rank:dt_rank + n].astype(jnp.float32)    # (B, L, n)
    Cv = proj[..., dt_rank + n:].astype(jnp.float32)           # (B, L, n)
    A = -jnp.exp(p["A_log"])                                   # (di, n)
    xi32 = xi.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, di, n), jnp.float32)

    if getattr(cfg, "ssm_impl", "ssd") == "naive":
        a = jnp.exp(dt[..., None] * A)                         # (B, L, di, n)
        bterm = (dt * xi32)[..., None] * Bv[:, :, None, :]
        hs, h_final = _scan_chunked(a, bterm, h0, chunk)
        y = jnp.einsum("bldn,bln->bld", hs, Cv)
    else:
        Q = min(chunk, L)
        while L % Q:
            Q -= 1
        nc = L // Q

        def rc(t):
            return t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)

        def combine(u, v):
            (au, bu), (av, bv_) = u, v
            return au * av, av * bu + bv_

        def chunk_step(h, cx):
            dt_c, xi_c, B_c, C_c = cx
            a_c = jnp.exp(dt_c[..., None] * A)                 # (B,Q,di,n)
            b_c = (dt_c * xi_c)[..., None] * B_c[:, :, None, :]
            pa, pb = lax.associative_scan(combine, (a_c, b_c), axis=1)
            hs = pa * h[:, None] + pb
            y_c = jnp.einsum("bqdn,bqn->bqd", hs, C_c)
            return hs[:, -1], y_c

        h_final, ys = lax.scan(chunk_step, h0,
                               (rc(dt), rc(xi32), rc(Bv), rc(Cv)))
        y = ys.swapaxes(0, 1).reshape(B, L, di)
    y = y + p["D"] * xi32
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return jnp.einsum("blc,cd->bld", y, p["out_proj"]), (conv_tail, h_final)


def mamba1_decode(p, cfg: ModelConfig, x, conv_state, h):
    """One-token decode.  x: (B, 1, d); conv_state: (B, K-1, di); h: (B, di, n)."""
    di, n = cfg.d_inner, cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                          # (B, 1, di)
    window = jnp.concatenate([conv_state, xi], axis=1)         # (B, K, di)
    new_conv = window[:, 1:]
    xi = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"])
                     + p["conv_b"])[:, None]
    proj = jnp.einsum("blc,ce->ble", xi, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("blr,rc->blc", proj[..., :dt_rank], p["dt_proj"])
        + p["dt_bias"])[:, 0].astype(jnp.float32)              # (B, di)
    Bv = proj[:, 0, dt_rank:dt_rank + n].astype(jnp.float32)
    Cv = proj[:, 0, dt_rank + n:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)
    h = a * h + (dt * xi[:, 0].astype(jnp.float32))[..., None] * Bv[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cv) + p["D"] * xi[:, 0].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    return jnp.einsum("blc,cd->bld", y, p["out_proj"]), (new_conv, h)


# --------------------------------------------------------------------------
# mamba2 (zamba2) — scalar-decay-per-head SSD
# --------------------------------------------------------------------------

def mamba2_seq_naive(p, cfg: ModelConfig, x, h0=None, chunk: int = 128):
    """Reference mamba2: elementwise chunked associative scan.  Materializes
    the (B, Q, H, dh, n) state tensor per chunk — the memory wall the SSD
    form removes (kept as the numerical oracle; see mamba2_seq)."""
    B, L, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads or di // 64
    dh = di // H
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_tail = xi[:, -(cfg.ssm_conv - 1):, :]
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))

    bc = jnp.einsum("bld,de->ble", x, p["bc_proj"]).astype(jnp.float32)
    Bv, Cv = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(jnp.einsum("bld,dh->blh", x, p["dt_w"])
                         + p["dt_bias"]).astype(jnp.float32)   # (B, L, H)
    A = -jnp.exp(p["A_log"])                                   # (H,)
    a = jnp.exp(dt * A)                                        # (B, L, H)

    xh = xi.reshape(B, L, H, dh).astype(jnp.float32)
    bterm = (dt[..., None, None] * xh[..., None]
             * Bv[:, :, None, None, :])                        # (B,L,H,dh,n)
    a_full = jnp.broadcast_to(a[..., None, None], bterm.shape)
    if h0 is None:
        h0 = jnp.zeros((B, H, dh, n), jnp.float32)
    hs, h_final = _scan_chunked(a_full, bterm, h0, chunk)
    y = jnp.einsum("blhdn,bln->blhd", hs, Cv).reshape(B, L, di)
    y = y + p["D"] * xi.astype(jnp.float32)
    y = rms_norm(y.astype(x.dtype), p["norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return jnp.einsum("blc,cd->bld", y, p["out_proj"]), (conv_tail, h_final)


def mamba2_seq(p, cfg: ModelConfig, x, h0=None, chunk: int = 128):
    """Mamba2 in the SSD matmul form (Dao & Gu 2024), TPU-adapted.

    Per chunk of length Q the scalar-decay recurrence collapses to
      y_intra[t] = sum_{s<=t} exp(cum_t - cum_s) * (C_t . B_s) * dt_s * x_s
    — an attention-like (B, H, Q, Q) matmul — plus a carried-state term and
    a decay-weighted state update, all MXU matmuls.  The (B, Q, H, dh, n)
    elementwise-scan state tensor of the naive form never materializes:
    per-chunk live memory drops from Q*H*dh*n to Q*Q*H + H*dh*n floats
    (32x for zamba2's Q=128, dh=64, n=64).  Verified == mamba2_seq_naive."""
    B, L, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads or di // 64
    dh = di // H
    Q = min(chunk, L)
    while L % Q:
        Q -= 1
    nc = L // Q

    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_tail = xi[:, -(cfg.ssm_conv - 1):, :]
    xi = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))

    bc = jnp.einsum("bld,de->ble", x, p["bc_proj"]).astype(jnp.float32)
    Bv, Cv = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(jnp.einsum("bld,dh->blh", x, p["dt_w"])
                         + p["dt_bias"]).astype(jnp.float32)   # (B, L, H)
    A = -jnp.exp(p["A_log"])                                   # (H,)
    loga = dt * A                                              # (B, L, H) <= 0
    xh = xi.reshape(B, L, H, dh).astype(jnp.float32)

    def reshape_c(t):
        return t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    xs = (reshape_c(loga), reshape_c(dt), reshape_c(xh),
          reshape_c(Bv), reshape_c(Cv))
    if h0 is None:
        h0 = jnp.zeros((B, H, dh, n), jnp.float32)

    def chunk_step(h, cx):
        loga_c, dt_c, x_c, B_c, C_c = cx          # (B,Q,H),(B,Q,H),(B,Q,H,dh),(B,Q,n)x2
        cum = jnp.cumsum(loga_c, axis=1)           # (B, Q, H) log decay-to-t
        # intra-chunk: (B,H,Q,Q) decay+gate matrix, causal
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,H) t,s
        qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        causal = (ki <= qi)[None, :, :, None]
        decay = jnp.where(causal, jnp.exp(diff), 0.0)           # (B,Q,Q,H)
        cb = jnp.einsum("bqn,bsn->bqs", C_c, B_c)               # (B,Q,Q)
        M = decay * (cb[..., None] * dt_c[:, None, :, :])       # (B,Q,Q,H)
        y = jnp.einsum("bqsh,bshd->bqhd", M, x_c)               # (B,Q,H,dh)
        # carried state contribution: y += exp(cum) * (C_t . h0)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bqn,bhdn->bqhd", C_c, h)
        # state update: h' = exp(cum_Q) h + sum_s exp(cum_Q - cum_s) u_s,
        # contracted over s as one einsum — no (B,Q,H,dh,n) intermediate
        tail = jnp.exp(cum[:, -1:, :] - cum)                    # (B,Q,H)
        h = jnp.exp(cum[:, -1])[..., None, None] * h + jnp.einsum(
            "bqh,bqn,bqhd->bhdn", dt_c * tail, B_c, x_c)
        return h, y

    h_final, ys = lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, L, di)
    y = y + p["D"] * xi.astype(jnp.float32)
    y = rms_norm(y.astype(x.dtype), p["norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    return jnp.einsum("blc,cd->bld", y, p["out_proj"]), (conv_tail, h_final)


def mamba2_decode(p, cfg: ModelConfig, x, conv_state, h):
    B = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads or di // 64
    dh = di // H
    xz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_state, xi], axis=1)
    new_conv = window[:, 1:]
    xi = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"])
                     + p["conv_b"])                            # (B, di)
    bc = jnp.einsum("bd,de->be", x[:, 0], p["bc_proj"]).astype(jnp.float32)
    Bv, Cv = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(jnp.einsum("bd,dh->bh", x[:, 0], p["dt_w"])
                         + p["dt_bias"]).astype(jnp.float32)   # (B, H)
    a = jnp.exp(dt * -jnp.exp(p["A_log"]))                     # (B, H)
    xh = xi.reshape(B, H, dh).astype(jnp.float32)
    h = (a[..., None, None] * h
         + dt[..., None, None] * xh[..., None] * Bv[:, None, None, :])
    y = jnp.einsum("bhdn,bn->bhd", h, Cv).reshape(B, di)
    y = y + p["D"] * xi.astype(jnp.float32)
    y = rms_norm(y.astype(x.dtype), p["norm_scale"], cfg.norm_eps)
    y = (y * jax.nn.silu(z[:, 0]))[:, None]
    return jnp.einsum("blc,cd->bld", y, p["out_proj"]), (new_conv, h)

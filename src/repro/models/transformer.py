"""Top-level model: init / train_loss / prefill / decode_step for all
assigned architecture families.

Layers are stacked (leading L dim) and driven by ``lax.scan`` so the lowered
HLO stays compact for 61-80-layer models.  Families:

  dense / vlm / encoder : attention + SwiGLU MLP
  moe / mla_moe         : attention (GQA or MLA) + MoE FFN
  ssm                   : mamba1 blocks (attention-free)
  hybrid                : mamba2 backbone + ONE weight-shared attention+MLP
                          block applied every ``attn_every`` layers (zamba2)

Distribution is carried by ``Dist`` (mesh + axis names); everything else is
global-semantics einsum, partitioned by GSPMD according to the shardings in
``launch/shardings.py``.  The MoE FFN switches between the dense reference,
shard_map expert-parallel a2a, and decode-time weight gathering.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from .attention import (gqa_decode, gqa_forward, init_attn, mla_decode,
                        mla_forward)
from .common import ModelConfig, shard_map
from .layers import dense_init, rms_norm, softmax_cross_entropy, swiglu
from .mamba import (init_mamba, mamba1_decode, mamba1_seq, mamba2_decode,
                    mamba2_seq)
from .moe import (init_moe, moe_dense, moe_ep_a2a, moe_ep_a2a_decode,
                  moe_gather)


@dataclass(frozen=True)
class Dist:
    """Distribution context threaded through the model."""
    mesh: Any = None
    batch_axes: tuple = ("data",)
    model_axis: str = "model"

    @property
    def active(self) -> bool:
        return self.mesh is not None


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_mlp(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": dense_init(k1, (d, ff), dtype=dtype),
            "up": dense_init(k2, (d, ff), dtype=dtype),
            "down": dense_init(k3, (ff, d), dtype=dtype)}


def _init_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm":
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "mamba": init_mamba(ks[0], cfg, dtype)}
    if cfg.family == "hybrid":
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "mamba": init_mamba(ks[0], cfg, dtype)}
    p = {"ln1": jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.ones((cfg.d_model,), dtype),
         "attn": init_attn(ks[0], cfg, dtype)}
    if cfg.n_experts:
        p["moe"] = init_moe(ks[1], cfg, dtype,
                            n_expert_shards=cfg.expert_shards)
    else:
        p["mlp"] = _init_mlp(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key=None, dtype=None) -> dict:
    key = jax.random.PRNGKey(0) if key is None else key
    dtype = dtype or _dtype(cfg)
    k_emb, k_lay, k_out, k_sh = jax.random.split(key, 4)
    params = {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), in_axis=-1,
                            dtype=dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "out": dense_init(k_out, (cfg.d_model, cfg.vocab), dtype=dtype),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype))(
            jax.random.split(k_lay, cfg.n_layers)),
    }
    if cfg.family == "hybrid":  # one weight-shared attention + MLP block
        ka, km = jax.random.split(k_sh)
        params["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": init_attn(ka, cfg, dtype),
            "mlp": _init_mlp(km, cfg, dtype),
        }
    return params


# --------------------------------------------------------------------------
# FFN dispatch
# --------------------------------------------------------------------------

def _moe_apply(p, cfg: ModelConfig, x, dist: Dist, decoding: bool):
    if decoding:
        if cfg.moe_mode == "ep_a2a" and dist.active:
            # EP decode: tokens striped over the expert axis, a2a dispatch;
            # moves O(tokens*d) on the wire instead of O(topk*d*ff) weight
            # gathers per token (1000x on the 671B decode cell, §Perf)
            pspec = {"router": P(), "w_gate": P(dist.model_axis),
                     "w_up": P(dist.model_axis), "w_down": P(dist.model_axis)}
            if "sh_gate" in p:
                pspec.update({"sh_gate": P(), "sh_up": P(), "sh_down": P()})

            def local_dec(xb, pp):  # xb: (B_loc, 1, d), replicated on model
                bl, sl, d = xb.shape
                y = moe_ep_a2a_decode(pp, cfg, xb.reshape(bl * sl, d),
                                      expert_axis=dist.model_axis)
                return y.reshape(bl, sl, d)

            fn = shard_map(
                local_dec, mesh=dist.mesh,
                in_specs=(P(dist.batch_axes, None, None), pspec),
                out_specs=P(dist.batch_axes, None, None), check_vma=False)
            return fn(x, p)
        return moe_gather(p, cfg, x)
    if cfg.moe_mode == "ep_a2a" and dist.active:
        pspec = {"router": P(), "w_gate": P(dist.model_axis),
                 "w_up": P(dist.model_axis), "w_down": P(dist.model_axis)}
        if "sh_gate" in p:
            pspec.update({"sh_gate": P(), "sh_up": P(), "sh_down": P()})
        def local_moe(xb, pp):  # xb: (B_loc, S_loc, d) block
            bl, sl, d = xb.shape
            y = moe_ep_a2a(pp, cfg, xb.reshape(bl * sl, d),
                           expert_axis=dist.model_axis)
            return y.reshape(bl, sl, d)

        fn = shard_map(
            local_moe, mesh=dist.mesh,
            in_specs=(P(dist.batch_axes, dist.model_axis, None), pspec),
            out_specs=P(dist.batch_axes, dist.model_axis, None),
            check_vma=False)
        return fn(x, p)
    return moe_dense(p, cfg, x)


# --------------------------------------------------------------------------
# layer bodies
# --------------------------------------------------------------------------

def _attn_layer_fwd(lp, cfg, x, positions, dist, decoding=False,
                    cache=None, index=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla:
        if decoding:
            a, new_cache = mla_decode(lp["attn"], cfg, h, cache[0], cache[1],
                                      index, positions)
        else:
            a, new_cache = mla_forward(lp["attn"], cfg, h, positions,
                                       dist=dist)
    else:
        if decoding:
            a, new_cache = gqa_decode(lp["attn"], cfg, h, cache[0], cache[1],
                                      index, positions)
        else:
            a, new_cache = gqa_forward(lp["attn"], cfg, h, positions,
                                       dist=dist)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        f = _moe_apply(lp["moe"], cfg, h, dist, decoding)
    else:
        f = swiglu(h, lp["mlp"]["gate"], lp["mlp"]["up"], lp["mlp"]["down"])
    return x + f, new_cache


def _mamba_layer_fwd(lp, cfg, x, decoding=False, cache=None):
    from .mamba import mamba2_seq_naive
    if cfg.ssm_version == 1:
        seq = mamba1_seq
    else:
        seq = mamba2_seq if cfg.ssm_impl == "ssd" else mamba2_seq_naive
    dec = mamba1_decode if cfg.ssm_version == 1 else mamba2_decode
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    if decoding:
        y, new_cache = dec(lp["mamba"], cfg, h, cache[0], cache[1])
    else:
        y, new_cache = seq(lp["mamba"], cfg, h)
    return x + y, new_cache


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    """Per-layer decode caches, stacked with a leading layer dim."""
    dtype = dtype or _dtype(cfg)
    L = cfg.n_layers
    if cfg.family == "ssm" or cfg.family == "hybrid":
        di, n = cfg.d_inner, cfg.ssm_state
        if cfg.ssm_version == 1:
            h = jnp.zeros((L, batch, di, n), jnp.float32)
        else:
            H = cfg.ssm_heads or di // 64
            h = jnp.zeros((L, batch, H, di // H, n), jnp.float32)
        cache = {"conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, di), dtype),
                 "ssm": h}
        if cfg.family == "hybrid":
            G = cfg.n_layers // cfg.attn_every
            cache["shared_k"] = jnp.zeros(
                (G, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
            cache["shared_v"] = jnp.zeros(
                (G, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
        return cache
    if cfg.mla:
        return {"ckv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dtype)}
    return {"k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)}


# --------------------------------------------------------------------------
# full stack
# --------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, batch: dict):
    if cfg.embedding_inputs:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    B, S = x.shape[:2]
    if cfg.mrope:
        positions = batch["positions3"]          # (B, S, 3)
    else:
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)))
    return x, positions


def _stack_attn(params, cfg, x, positions, dist, decoding=False,
                caches=None, index=None):
    """scan over stacked attention-family layers."""
    mla = cfg.mla

    def body(carry, xs):
        h = carry
        if decoding:
            lp, c0, c1 = xs
            cache = (c0, c1)
        else:
            lp, cache = xs, None
        out, new_cache = _attn_layer_fwd(lp, cfg, h, positions, dist,
                                         decoding, cache, index)
        return out, new_cache

    fn = jax.checkpoint(body) if cfg.remat == "full" and not decoding else body
    if decoding:
        names = ("ckv", "krope") if mla else ("k", "v")
        xs = (params["layers"], caches[names[0]], caches[names[1]])
        x, (nc0, nc1) = lax.scan(fn, x, xs)
        return x, {names[0]: nc0, names[1]: nc1}
    x, (nc0, nc1) = lax.scan(fn, x, params["layers"])
    names = ("ckv", "krope") if mla else ("k", "v")
    return x, {names[0]: nc0, names[1]: nc1}


def _stack_ssm(params, cfg, x, dist, decoding=False, caches=None):
    def body(carry, xs):
        if decoding:
            lp, conv, h = xs
            out, (nconv, nh) = _mamba_layer_fwd(lp, cfg, carry, True,
                                                (conv, h))
        else:
            lp = xs
            out, (nconv, nh) = _mamba_layer_fwd(lp, cfg, carry, False)
        return out, (nconv, nh)

    fn = jax.checkpoint(body) if cfg.remat == "full" and not decoding else body
    if decoding:
        xs = (params["layers"], caches["conv"], caches["ssm"])
    else:
        xs = params["layers"]
    x, (nconv, nh) = lax.scan(fn, x, xs)
    return x, {"conv": nconv, "ssm": nh}


def _stack_hybrid(params, cfg, x, positions, dist, decoding=False,
                  caches=None, index=None):
    """zamba2: groups of ``attn_every`` mamba layers + shared attn block.
    Leftover layers (n_layers % attn_every) run as a trailing mamba-only
    scan with no shared-block invocation."""
    k = cfg.attn_every
    G = cfg.n_layers // k
    rem = cfg.n_layers - G * k
    shared = params["shared"]

    def regroup(t):
        return t[:G * k].reshape((G, k) + t.shape[1:])

    def tail(t):
        return t[G * k:]

    layers_g = jax.tree.map(regroup, params["layers"])

    def group_body(carry, xs):
        h = carry
        if decoding:
            lp_g, conv_g, ssm_g, sk, sv = xs
        else:
            lp_g = xs

        def inner(c, ixs):
            if decoding:
                lp, conv, ssm = ixs
                out, ncache = _mamba_layer_fwd(lp, cfg, c, True, (conv, ssm))
            else:
                lp = ixs
                out, ncache = _mamba_layer_fwd(lp, cfg, c, False)
            return out, ncache

        if decoding:
            h, (nconv, nssm) = lax.scan(inner, h, (lp_g, conv_g, ssm_g))
        else:
            h, (nconv, nssm) = lax.scan(inner, h, lp_g)
        # weight-shared attention + MLP block
        hh = rms_norm(h, shared["ln1"], cfg.norm_eps)
        if decoding:
            a, (nsk, nsv) = gqa_decode(shared["attn"], cfg, hh, sk, sv,
                                       index, positions)
        else:
            a, (nsk, nsv) = gqa_forward(shared["attn"], cfg, hh, positions,
                                        dist=dist)
        h = h + a
        hh = rms_norm(h, shared["ln2"], cfg.norm_eps)
        h = h + swiglu(hh, shared["mlp"]["gate"], shared["mlp"]["up"],
                       shared["mlp"]["down"])
        return h, (nconv, nssm, nsk, nsv)

    fn = (jax.checkpoint(group_body)
          if cfg.remat == "full" and not decoding else group_body)
    if decoding:
        conv_g = regroup(caches["conv"])
        ssm_g = regroup(caches["ssm"])
        xs = (layers_g, conv_g, ssm_g, caches["shared_k"], caches["shared_v"])
    else:
        xs = layers_g
    x, (nconv, nssm, nsk, nsv) = lax.scan(fn, x, xs)
    nconv = nconv.reshape((G * k,) + nconv.shape[2:])
    nssm = nssm.reshape((G * k,) + nssm.shape[2:])
    if rem:  # trailing mamba-only layers
        def tail_body(carry, ixs):
            if decoding:
                lp, conv, ssm = ixs
                out, nc = _mamba_layer_fwd(lp, cfg, carry, True, (conv, ssm))
            else:
                lp = ixs
                out, nc = _mamba_layer_fwd(lp, cfg, carry, False)
            return out, nc

        tl = jax.tree.map(tail, params["layers"])
        if decoding:
            txs = (tl, tail(caches["conv"]), tail(caches["ssm"]))
        else:
            txs = tl
        x, (tconv, tssm) = lax.scan(tail_body, x, txs)
        nconv = jnp.concatenate([nconv, tconv], axis=0)
        nssm = jnp.concatenate([nssm, tssm], axis=0)
    out_caches = {"conv": nconv, "ssm": nssm,
                  "shared_k": nsk, "shared_v": nsv}
    return x, out_caches


def _forward(params, cfg: ModelConfig, batch: dict, dist: Dist,
             decoding=False, caches=None, index=None):
    """Returns (final hidden states (B, S, d), new caches)."""
    x, positions = _embed(params, cfg, batch)
    if cfg.family == "ssm":
        x, new_caches = _stack_ssm(params, cfg, x, dist, decoding, caches)
    elif cfg.family == "hybrid":
        x, new_caches = _stack_hybrid(params, cfg, x, positions, dist,
                                      decoding, caches, index)
    else:
        x, new_caches = _stack_attn(params, cfg, x, positions, dist,
                                    decoding, caches, index)
    return rms_norm(x, params["ln_f"], cfg.norm_eps), new_caches


def _head(params, h):
    return jnp.einsum("...d,dv->...v", h, params["out"])


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def train_loss(params, batch: dict, cfg: ModelConfig,
               dist: Dist = Dist()) -> jax.Array:
    """Mean next-token (or frame-label for encoders) cross-entropy.

    With ``cfg.loss_chunk`` the vocabulary head + xent run per sequence
    chunk inside a scan, so the (B, S, V) logits tensor (the dominant
    training memory term for 150k-vocab models) never materializes."""
    h, _ = _forward(params, cfg, batch, dist)
    labels = batch["labels"]
    if cfg.loss_chunk and h.shape[1] % cfg.loss_chunk == 0 \
            and h.shape[1] > cfg.loss_chunk:
        B, S, d = h.shape
        nc = S // cfg.loss_chunk
        hc = h.reshape(B, nc, cfg.loss_chunk, d).swapaxes(0, 1)
        lc = labels.reshape(B, nc, cfg.loss_chunk).swapaxes(0, 1)

        def body(acc, xs):
            hx, lx = xs
            losses = softmax_cross_entropy(_head(params, hx), lx)
            return acc + jnp.sum(losses), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
        return total / (B * S)
    return jnp.mean(softmax_cross_entropy(_head(params, h), labels))


def prefill(params, batch: dict, cfg: ModelConfig, dist: Dist = Dist()):
    """Full-sequence forward; returns (last-position logits, caches of
    length S for continuation).  The vocab head runs on the LAST position
    only — serving never needs the (B, S, V) logits."""
    h, caches = _forward(params, cfg, batch, dist)
    return _head(params, h[:, -1]), caches


def decode_step(params, token_batch: dict, caches: dict, index,
                cfg: ModelConfig, dist: Dist = Dist()):
    """One decode step.  ``token_batch`` holds (B, 1) tokens (or (B,1,d)
    embeds) plus positions; ``index`` is the current cache length."""
    h, new_caches = _forward(params, cfg, token_batch, dist,
                             decoding=True, caches=caches, index=index)
    return _head(params, h[:, -1]), new_caches

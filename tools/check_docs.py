"""Docs link-and-command checker (the CI docs job).

Over the repo's user-facing markdown (README, DESIGN, EXPERIMENTS, ROADMAP,
docs/*.md), verifies that:

* every **relative link** ``[text](path)`` resolves to an existing file or
  directory (anchors are stripped; http(s)/mailto links are skipped), and
* every **referenced command entry point** exists: ``python -m pkg.mod``
  resolves to a module under ``src/`` or the repo root, and
  ``python <path>.py`` scripts exist.

Exits non-zero listing every broken reference, so stale docs fail CI the
same way broken imports do.

    python tools/check_docs.py
"""

from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

DOC_GLOBS = ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md",
             "PAPER.md", "docs/*.md")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MODULE_RE = re.compile(r"python\s+(?:-W\S+\s+)?-m\s+([A-Za-z0-9_.]+)")
SCRIPT_RE = re.compile(r"python\s+((?:[A-Za-z0-9_./-]+/)?[A-Za-z0-9_.-]+\.py)")


def doc_files() -> list[str]:
    out = []
    for pat in DOC_GLOBS:
        out.extend(sorted(glob.glob(os.path.join(ROOT, pat))))
    return out


# third-party tools docs legitimately invoke with `python -m`
EXTERNAL_MODULES = {"pytest", "pip"}

# user-facing CLIs that MUST be documented: each of these entry points has
# to be referenced (as `python -m <mod>`) somewhere in the checked files,
# so shipping a CLI without docs fails the same gate as stale docs
REQUIRED_ENTRY_POINTS = {
    "repro.core.analysis",
    "repro.core.deploy",
    "repro.core.deploy.router",
    "repro.core.liveloop",
    "repro.core.surrogate",
    "repro.launch.serve",
    "benchmarks.perf_ab",
    "benchmarks.report",
}


def module_exists(mod: str) -> bool:
    if mod.split(".", 1)[0] in EXTERNAL_MODULES:
        return True
    rel = mod.replace(".", os.sep)
    for base in (os.path.join(ROOT, "src"), ROOT):
        if os.path.exists(os.path.join(base, rel + ".py")) or \
                os.path.exists(os.path.join(base, rel, "__init__.py")) or \
                os.path.exists(os.path.join(base, rel, "__main__.py")):
            return True
    return False


def check_file(path: str, seen_modules: set[str] | None = None) -> list[str]:
    errors = []
    if seen_modules is None:
        seen_modules = set()
    text = open(path).read()
    rel = os.path.relpath(path, ROOT)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target.split("#", 1)[0]))
        if not os.path.exists(resolved):
            errors.append(f"{rel}: broken link -> {target}")
    for mod in MODULE_RE.findall(text):
        seen_modules.add(mod)
        if not module_exists(mod):
            errors.append(f"{rel}: missing module entry point -> "
                          f"python -m {mod}")
    for script in SCRIPT_RE.findall(text):
        if not os.path.exists(os.path.join(ROOT, script)):
            errors.append(f"{rel}: missing script -> python {script}")
    return errors


def main() -> int:
    files = doc_files()
    errors = []
    seen_modules: set[str] = set()
    for f in files:
        errors.extend(check_file(f, seen_modules))
    for mod in sorted(REQUIRED_ENTRY_POINTS - seen_modules):
        errors.append(f"required CLI undocumented -> python -m {mod} "
                      f"appears in none of the checked files")
    print(f"checked {len(files)} markdown files")
    for e in errors:
        print(f"  BROKEN  {e}")
    if errors:
        print(f"{len(errors)} broken doc reference(s)")
        return 1
    print("all links and referenced entry points resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness — one entry per paper table/figure + framework perf.

  fig4b_2fcnet_training     Pareto front, 2fcNet training (paper Fig. 4b)
  fig4a_mobilenet_prediction Pareto front, MobileNet prediction (paper Fig. 4a)
  sec42_crossover_validity  messy-crossover validity rate (~80% in paper)
  sec61_mutation_analysis   key mutations of the best individuals (Sec 6.1/6.2)
  kernels                   Pallas kernel wall time vs jnp oracle (interpret)
  kernel_schedule_search    GEVO over a kernel's schedule space (attr_tweak)
  roofline_table            per-cell roofline terms from the dry-run records

Prints ``name,us_per_call,derived`` CSV rows (derived carries the
benchmark-specific headline number).  ``--full`` raises search budgets
toward the paper's scale.  ``--parallel N`` runs the search benches through
an N-worker ParallelEvaluator; ``--cache-dir D`` gives them a persistent
fitness cache (rerun to see hit rates climb); ``--operators SPEC`` picks the
edit-operator mix ("all", "legacy", or "name=w,...").  Serial-vs-parallel
and legacy-vs-five-operator A/B timing live in ``benchmarks/perf_ab.py``
(``--suite evaluator`` / ``--suite operators``).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


# Evaluation-engine / edit-layer options for the search benches
# (set in main()).
OPTS = {"parallel": 0, "cache_dir": None, "operators": "all"}


def _make_evaluator(workload, tag: str):
    from repro.core.evaluator import make_evaluator

    cache_path = (os.path.join(OPTS["cache_dir"], f"{tag}.jsonl")
                  if OPTS["cache_dir"] else None)
    return make_evaluator(workload, parallel=OPTS["parallel"],
                          cache_path=cache_path)


def _operator_weights():
    from repro.core.edits import OperatorWeights

    return OperatorWeights.parse(OPTS["operators"])


# ---------------------------------------------------------------------------

def bench_2fcnet(full: bool) -> None:
    from repro.core.search import GevoML
    from repro.workloads.twofc import build_twofc_training_workload

    steps = 200 if full else 80
    w = build_twofc_training_workload(batch=32, hidden=64, steps=steps,
                                      n_train=4096, n_test=2000, lr=0.01)
    t0 = time.perf_counter()
    s = GevoML(w, pop_size=16 if full else 12, n_elite=8 if full else 6,
               seed=0, operators=_operator_weights(),
               evaluator=_make_evaluator(w, "fig4b_2fcnet"))
    res = s.run(generations=8 if full else 5)
    wall = time.perf_counter() - t0
    s.evaluator.close()
    to, eo = res.original_fitness
    be = res.best_by_error()
    bt = res.best_by_time()
    _row("fig4b_2fcnet_search", wall * 1e6,
         f"orig(t={to:.3e};err={eo:.4f})"
         f" best_err={be.fitness[1]:.4f}"
         f" best_time={bt.fitness[0]:.3e}"
         f" err_improve={eo - be.fitness[1]:+.4f}"
         f" pareto={len(res.pareto)} evals={s.n_evals}"
         f" cache_hit={s.cache.hit_rate:.0%}")
    for i, ind in enumerate(res.pareto[:8]):
        _row(f"fig4b_pareto_{i}", 0.0,
             f"t={ind.fitness[0]:.3e};err={ind.fitness[1]:.4f}")


def bench_mobilenet(full: bool) -> None:
    from repro.core.search import GevoML
    from repro.workloads.mobilenet import build_mobilenet_prediction_workload

    w = build_mobilenet_prediction_workload(
        alpha=0.25,                       # 0.125 pretrains to ~random acc
        n_eval=2048 if full else 512,
        n_pretrain=6000 if full else 4000,
        pretrain_epochs=4 if full else 2)
    t0 = time.perf_counter()
    s = GevoML(w, pop_size=12 if full else 10, n_elite=6 if full else 5,
               seed=0, operators=_operator_weights(),
               evaluator=_make_evaluator(w, "fig4a_mobilenet"))
    res = s.run(generations=6 if full else 4)
    wall = time.perf_counter() - t0
    s.evaluator.close()
    to, eo = res.original_fitness
    bt = res.best_by_time()
    # paper headline: % runtime improvement at <=2% accuracy loss
    ok = [i for i in res.pareto if i.fitness[1] <= eo + 0.02]
    fastest_ok = min(ok, key=lambda i: i.fitness[0]) if ok else bt
    speedup = (to - fastest_ok.fitness[0]) / to * 100
    _row("fig4a_mobilenet_search", wall * 1e6,
         f"orig(t={to:.3e};err={eo:.4f})"
         f" runtime_improve@2%acc={speedup:.1f}%"
         f" pareto={len(res.pareto)} evals={s.n_evals}"
         f" cache_hit={s.cache.hit_rate:.0%}")
    for i, ind in enumerate(res.pareto[:8]):
        _row(f"fig4a_pareto_{i}", 0.0,
             f"t={ind.fitness[0]:.3e};err={ind.fitness[1]:.4f}")


def bench_crossover(full: bool) -> None:
    from repro.core.crossover import messy_crossover
    from repro.core.edits import (EditError, OperatorWeights, apply_patch,
                                  sample_edit)
    from repro.workloads.twofc import build_twofc_step

    p = build_twofc_step(batch=8, in_dim=32, hidden=16)
    rng = np.random.default_rng(0)
    legacy = OperatorWeights.legacy()  # the paper's copy/delete pair

    def grow(n):
        edits = []
        while len(edits) < n:
            try:
                q = apply_patch(p, edits)
                e = sample_edit(q, rng, legacy)
                apply_patch(p, edits + [e])
                edits.append(e)
            except EditError:
                continue
        return edits

    trials = 120 if full else 60
    ok = tot = 0
    t0 = time.perf_counter()
    for _ in range(trials):
        a, b = messy_crossover(grow(3), grow(3), rng)
        for child in (a, b):
            tot += 1
            try:
                apply_patch(p, child)
                ok += 1
            except EditError:
                pass
    _row("sec42_crossover_validity", (time.perf_counter() - t0) / tot * 1e6,
         f"valid={ok}/{tot}({100*ok/tot:.0f}%) paper~80%")


def bench_mutation_analysis(full: bool) -> None:
    from repro.core.edits import minimize_patch
    from repro.core.evaluator import SerialEvaluator
    from repro.core.search import GevoML
    from repro.workloads.twofc import build_twofc_training_workload

    w = build_twofc_training_workload(batch=32, hidden=32, steps=80,
                                      n_train=2048, n_test=512, lr=0.01)
    t0, e0 = w.evaluate(w.program)
    # mutation analysis is about the best-found individual; sweep a few
    # seeds (searches are seconds at this scale) and analyze the winner
    best, best_ev = None, None
    for seed in (0, 1, 2):
        ev = SerialEvaluator(w)
        s = GevoML(w, pop_size=10, n_elite=5, seed=seed,
                   operators=_operator_weights(), evaluator=ev)
        res = s.run(generations=4)
        cand = res.best_by_error()
        if best is None or cand.fitness[1] < best.fitness[1]:
            best, best_ev = cand, ev
    # GEVO-style key-mutation isolation: ddmin against the winner's warm
    # fitness cache, so minimization re-measures only unseen sub-patches
    key_patch, _ = minimize_patch(best.patch, best_ev,
                                  expect_fitness=best.fitness)
    _row("sec62_best_training_patch", 0.0,
         f"orig_err={e0:.4f} best_err={best.fitness[1]:.4f} "
         f"edits=[{best.patch.describe()}] "
         f"key_mutations=[{key_patch.describe()}]")


def bench_kernels(full: bool) -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.mamba_scan.ops import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref

    def timeit(fn, *args, n=3):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n * 1e6

    k = jax.random.PRNGKey
    q = jax.random.normal(k(0), (1, 2, 256, 64))
    kk = jax.random.normal(k(1), (1, 2, 256, 64))
    v = jax.random.normal(k(2), (1, 2, 256, 64))
    _row("kernel_flash_attention_interp", timeit(flash_attention, q, kk, v),
         f"ref_us={timeit(attention_ref, q, kk, v):.1f} (interpret mode; "
         "wall time is NOT TPU-indicative)")
    dt = jax.nn.softplus(jax.random.normal(k(3), (1, 128, 16)))
    x = jax.random.normal(k(4), (1, 128, 16))
    A = -jnp.exp(jax.random.normal(k(5), (16, 8)) * 0.3)
    B = jax.random.normal(k(6), (1, 128, 8))
    C = jax.random.normal(k(7), (1, 128, 8))
    _row("kernel_mamba_scan_interp", timeit(mamba_scan, dt, x, A, B, C),
         f"ref_us={timeit(mamba_scan_ref, dt, x, A, B, C):.1f}")
    xx = jax.random.normal(k(8), (512, 512))
    sc = jnp.ones(512)
    _row("kernel_rmsnorm_interp", timeit(rmsnorm, xx, sc),
         f"ref_us={timeit(rmsnorm_ref, xx, sc):.1f}")


def bench_kernel_schedule_search(full: bool) -> None:
    """GEVO over the Pallas kernel schedule spaces: evolve (impl, blocks,
    epilogue) genomes with the attr_tweak operator; headline is the modeled
    speedup of the best evolved schedule over the kernel's shipped default
    (error held within 1e-3 of the default's)."""
    from repro.kernels.workloads import (KERNELS, build_kernel_workload,
                                         evolve_kernel_schedule)

    gens = 8 if full else 6
    for kernel in KERNELS:
        w = build_kernel_workload(kernel, time_mode="static")
        t_def, _ = w.evaluate(w.program)
        t0 = time.perf_counter()
        s, res, best, within_tol = evolve_kernel_schedule(
            w, generations=gens, seed=0)
        wall = time.perf_counter() - t0
        genome = w.space.decode(best.patch.apply(w.program))
        s.close()
        _row(f"kernel_search_{kernel}", wall * 1e6,
             f"default={t_def:.3e}s best={best.fitness[0]:.3e}s "
             f"speedup={t_def / best.fitness[0]:.2f}x "
             f"{'' if within_tol else '(OUT OF ERROR TOLERANCE) '}"
             f"schedule=[{';'.join(f'{k}={v}' for k, v in genome.items())}] "
             f"evals={s.n_evals} cache_hit={s.cache.hit_rate:.0%}")


def bench_roofline_table(full: bool) -> None:
    d = ("experiments/dryrun_final"
         if glob.glob("experiments/dryrun_final/*.json")
         else "experiments/dryrun")
    recs = []
    for f in sorted(glob.glob(f"{d}/*.json")):
        r = json.load(open(f))
        if r.get("status") == "ok":
            recs.append(r)
    if not recs:
        _row("roofline_table", 0.0, "no dryrun records (run repro.launch.dryrun)")
        return
    for r in recs:
        rl = r["roofline"]
        _row(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
             f"dom={rl['dominant']};frac={rl['roofline_fraction']:.4f};"
             f"c={rl['compute_s']:.3e};m={rl['memory_s']:.3e};"
             f"x={rl['collective_s']:.3e};useful={rl['useful_ratio']:.3f}")


BENCHES = {
    "fig4b_2fcnet": bench_2fcnet,
    "fig4a_mobilenet": bench_mobilenet,
    "sec42_crossover": bench_crossover,
    "sec62_mutation_analysis": bench_mutation_analysis,
    "kernels": bench_kernels,
    "kernel_schedule_search": bench_kernel_schedule_search,
    "roofline_table": bench_roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (slow)")
    ap.add_argument("--parallel", type=int, default=0,
                    help="evaluation workers for the search benches "
                         "(0/1 = serial)")
    ap.add_argument("--cache-dir", default=None,
                    help="directory for persistent fitness caches")
    ap.add_argument("--operators", default="all",
                    help='edit-operator mix for the search benches: "all", '
                         '"legacy", or "name=w,name=w,..."')
    args, _ = ap.parse_known_args()
    OPTS["parallel"] = args.parallel
    OPTS["cache_dir"] = args.cache_dir
    OPTS["operators"] = args.operators
    if args.cache_dir:
        os.makedirs(args.cache_dir, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        try:
            fn(args.full)
        except Exception as e:  # a failed bench must not hide the others
            _row(f"{name}_ERROR", 0.0, repr(e)[:200])


if __name__ == "__main__":
    main()

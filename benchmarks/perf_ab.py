"""§Perf A/B measurements for the three hillclimbed cells.

For each cell, measures (under the FINAL roofline analyzer, so numbers are
comparable) the paper-faithful BASELINE configuration and each optimization
step, writing experiments/perf/<cell>.json.  This is the machine-readable
source for the EXPERIMENTS.md §Perf iteration log.

  PYTHONPATH=src python -m benchmarks.perf_ab
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

OUT = "experiments/perf"


def run(tag: str, arch: str, shape: str, cfg, micro: int = 1) -> dict:
    path = os.path.join(OUT, f"{tag}.json")
    if os.path.exists(path):
        rec = json.load(open(path))
        if rec.get("status") == "ok":
            print(f"[cached] {tag}")
            return rec
    rec = run_cell(arch, shape, False, cfg_override=cfg, microbatches=micro)
    rec["tag"] = tag
    json.dump(rec, open(path, "w"), indent=1)
    rl = rec.get("roofline", {})
    print(f"[{rec['status']}] {tag}: step={rl.get('step_s', 0):.2f}s "
          f"dom={rl.get('dominant')} frac={rl.get('roofline_fraction', 0):.4f}")
    return rec


def main():
    os.makedirs(OUT, exist_ok=True)

    # ---- cell A: zamba2-1.2b train_4k (worst roofline fraction) ----------
    z = get_config("zamba2-1.2b")
    run("zamba2_train_0_baseline", "zamba2-1.2b", "train_4k",
        z.scaled(ssm_impl="naive"))
    run("zamba2_train_1_ssd", "zamba2-1.2b", "train_4k", z)  # ssd default
    run("zamba2_train_2_ssd_blockattn_remat", "zamba2-1.2b", "train_4k",
        z.scaled(attn_impl="blockwise", attn_block=512, remat="full"))
    run("zamba2_train_3_plus_losschunk", "zamba2-1.2b", "train_4k",
        z.scaled(attn_impl="blockwise", attn_block=512, remat="full",
                 loss_chunk=512))

    # ---- cell B: deepseek-v3-671b train_4k (most collective-bound) -------
    d = get_config("deepseek-v3-671b")
    run("deepseek_train_0_baseline", "deepseek-v3-671b", "train_4k",
        d.scaled(gnorm_vdot=True))
    run("deepseek_train_1_sharded_gnorm", "deepseek-v3-671b", "train_4k", d)
    run("deepseek_train_2_blockattn", "deepseek-v3-671b", "train_4k",
        d.scaled(attn_impl="blockwise", attn_block=512))
    run("deepseek_train_3_plus_losschunk", "deepseek-v3-671b", "train_4k",
        d.scaled(attn_impl="blockwise", attn_block=512, loss_chunk=512))

    # ---- cell C: qwen2-vl-72b prefill_32k (attention-memory-bound) -------
    q = get_config("qwen2-vl-72b")
    run("qwen2vl_prefill_0_baseline", "qwen2-vl-72b", "prefill_32k", q)
    run("qwen2vl_prefill_1_blockattn", "qwen2-vl-72b", "prefill_32k",
        q.scaled(attn_impl="blockwise", attn_block=512))
    run("qwen2vl_prefill_2_blockattn1k", "qwen2-vl-72b", "prefill_32k",
        q.scaled(attn_impl="blockwise", attn_block=1024))
    run("qwen2vl_prefill_3_nofsdp", "qwen2-vl-72b", "prefill_32k",
        q.scaled(attn_impl="blockwise", attn_block=512, fsdp=False))

    # ---- bonus D: falcon-mamba-7b train_4k (worst memory after resweep) ---
    f = get_config("falcon-mamba-7b")
    run("falcon_train_0_baseline", "falcon-mamba-7b", "train_4k",
        f.scaled(ssm_impl="naive"))
    run("falcon_train_1_chunked", "falcon-mamba-7b", "train_4k", f)
    run("falcon_train_2_chunked_remat", "falcon-mamba-7b", "train_4k",
        f.scaled(remat="full"))

    # ---- bonus E: deepseek-v3-671b decode_32k (weight-gather collectives) -
    run("deepseek_decode_0_gather", "deepseek-v3-671b", "decode_32k",
        d.scaled(moe_mode="dense"))
    run("deepseek_decode_1_ep_a2a", "deepseek-v3-671b", "decode_32k", d)


if __name__ == "__main__":
    main()

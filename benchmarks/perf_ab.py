"""§Perf A/B measurements.

Select with ``--suite {cells,evaluator,operators,kernels,islands,serving,
tensor_evo,analysis,surrogate,liveloop,sharded_serving,all}``:

* ``cells`` (default) — for each hillclimbed model cell, measures (under the
  FINAL roofline analyzer, so numbers are comparable) the paper-faithful
  BASELINE configuration and each optimization step, writing
  experiments/perf/<cell>.json.  This is the machine-readable source for the
  EXPERIMENTS.md §Perf iteration log.

* ``evaluator`` — A/Bs the GEVO-ML evaluation engine on the 2fcNet search:
  SerialEvaluator vs ParallelEvaluator (``--workers N``) generation
  wall-clock, plus a warm-persistent-cache rerun; reports per-generation
  wall time, evaluation counts, and cache hit rates, writing
  experiments/perf/evaluator_ab.json.

* ``operators`` — A/Bs the edit-operator mix on the 2fcNet search: the
  legacy ``{copy, delete}`` pair vs. the full five-operator registry
  (``swap``/``insert``/``const_perturb`` added), same seed and budget;
  reports valid-candidate rate, evals/sec, final Pareto hypervolume, and the
  per-operator proposed/valid/elite counters, writing
  experiments/perf/operators_ab.json (results quoted in EXPERIMENTS.md).

* ``kernels`` — A/Bs kernel-schedule search on the Pallas kernels
  (rmsnorm, flash_attention, mamba_scan): a random-schedule baseline vs
  GEVO-evolved schedules under the same evaluation budget, same
  schedule-aware roofline fitness; reports best modeled time vs the shipped
  default schedule, writing experiments/perf/kernels_ab.json (results
  quoted in EXPERIMENTS.md).

* ``islands`` — A/Bs the island-model orchestrator on the 2fcNet search:
  1 island vs 4 heterogeneous islands (pop 8 each, fully-connected
  migration, one shared fitness cache) at an equal unique-genome budget;
  reports Pareto hypervolume, cross-island cache hits, and the migration
  log, writing experiments/perf/islands_ab.json (results quoted in
  EXPERIMENTS.md).

* ``serving`` — A/Bs the deployment layer end to end: evolves the
  continuous-batching engine's serving schedule under measured fitness,
  exports the winner through the ArtifactRegistry, resolves it back from
  disk, and re-measures the default schedule vs the evolved-artifact route
  on the same staggered request trace, writing
  experiments/perf/serving_ab.json (results quoted in EXPERIMENTS.md).

* ``tensor_evo`` — A/Bs the tensorized on-device engine against the Python
  engine on the joint three-kernel schedule space: population-evals/sec of
  ``TensorGevoML`` at pop 1024 vs ``GevoML(engine="python")``, then reruns
  the islands-vs-panmictic comparison at >= 100x the PR-4 genome budget
  (4 mesh islands x pop 1024 x 4 generations = 16384 genome-evals vs the
  original 140) against an equal-budget panmictic tensor run, writing
  experiments/perf/tensor_evo_ab.json (results quoted in EXPERIMENTS.md).

* ``analysis`` — A/Bs the static patch screen (``core.analysis``) on the
  2fcNet IR search and the joint three-kernel schedule search: the same
  seeded ``GevoML`` run with and without the pre-execution classifier, at an
  equal genome budget.  Asserts the exported Pareto fronts are
  byte-identical (screening must not change the search, only skip
  executions) and that >= 20% of cache-missing mutants resolve statically;
  reports the skip rate, screen-verdict histogram, and the per-operator
  invalid/noop/equivalent table, writing experiments/perf/analysis_ab.json
  (results quoted in EXPERIMENTS.md).

* ``surrogate`` — A/Bs the surrogate pre-rank (``core.surrogate``) on the
  joint three-kernel schedule search: the same seeded ``GevoML`` run
  unguided vs guided by the cache-trained cost model, at an equal genome
  budget.  The guided arm generates offspring at the normal rate but only
  the model's predicted-Pareto slice reaches the evaluator.  Asserts the
  guided front's hypervolume is >= 1.0x the unguided front's while the
  guided arm executes <= 70% of the unguided arm's evaluations; reports
  both fronts, the executed-evaluation counts, and the per-operator
  ranked/kept table, writing experiments/perf/surrogate_ab.json (results
  quoted in EXPERIMENTS.md).

* ``liveloop`` — closes the full evolve->serve->measure->promote loop on a
  synthesized bursty trace (``core.liveloop``): a background GevoML island
  evolves the serve schedule against replayed traffic, the canary state
  machine promotes the winner under measured guardrails, and the promoted
  artifact must re-measure at >= 1.0x the default schedule's throughput on
  the real engine; a second, fault-injected run must be rolled back and
  its fingerprint blocked.  Writes experiments/perf/liveloop_ab.json
  (results quoted in EXPERIMENTS.md).

* ``sharded_serving`` — A/Bs the full serving plan (engine schedule + KV
  memory plan + replica layout) on the multi-replica router: GevoML
  evolves the joint 432-point SERVE_SPACE under (modeled s/token, measured
  quantized-cache decode error), the deployment rule
  ``select("time", on="error", limit=KV_ERROR_GATE)`` picks the winner,
  and the artifact is rebuilt as a real Router and re-measured against the
  default plan (bar: >= 1.0x) plus the same plan pinned to one replica on
  a 2x2 smoke mesh (bar: router >= single).  Writes
  experiments/perf/sharded_serving_ab.json (results quoted in
  EXPERIMENTS.md).

  PYTHONPATH=src python -m benchmarks.perf_ab
  PYTHONPATH=src python -m benchmarks.perf_ab --suite evaluator --workers 2
  PYTHONPATH=src python -m benchmarks.perf_ab --suite operators
  PYTHONPATH=src python -m benchmarks.perf_ab --suite kernels
  PYTHONPATH=src python -m benchmarks.perf_ab --suite islands
  PYTHONPATH=src python -m benchmarks.perf_ab --suite serving
  PYTHONPATH=src python -m benchmarks.perf_ab --suite tensor_evo
  PYTHONPATH=src python -m benchmarks.perf_ab --suite liveloop
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from contextlib import contextmanager  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

OUT = "experiments/perf"


@contextmanager
def pinned_xla_host_devices(count: int = 512):
    """Pin ``XLA_FLAGS`` host-device-count for one suite, restoring the
    previous value afterwards.

    jax reads ``XLA_FLAGS`` exactly once, at first backend initialization,
    so a suite whose numerics depend on the device count (the surrogate
    A/B's roofline/VMEM feature probes see per-device shapes) must pin the
    flag *and verify the backend actually honors it* — if another suite
    already initialized jax at a different count, re-exporting the flag is
    silently ignored.  This guard makes that failure loud instead of a
    numbers drift, which is what makes suites order-independent (see
    EXPERIMENTS.md)."""
    prev = os.environ.get("XLA_FLAGS")
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={count}"
    try:
        import jax
        n = jax.device_count()
        if n != count:
            print(f"[xla] WARNING: backend already initialized with {n} "
                  f"host devices (wanted {count}); results may differ "
                  f"from an isolated run of this suite", flush=True)
        yield
    finally:
        if prev is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = prev


def run(tag: str, arch: str, shape: str, cfg, micro: int = 1) -> dict:
    path = os.path.join(OUT, f"{tag}.json")
    if os.path.exists(path):
        rec = json.load(open(path))
        if rec.get("status") == "ok":
            print(f"[cached] {tag}")
            return rec
    rec = run_cell(arch, shape, False, cfg_override=cfg, microbatches=micro)
    rec["tag"] = tag
    json.dump(rec, open(path, "w"), indent=1)
    rl = rec.get("roofline", {})
    print(f"[{rec['status']}] {tag}: step={rl.get('step_s', 0):.2f}s "
          f"dom={rl.get('dominant')} frac={rl.get('roofline_fraction', 0):.4f}")
    return rec


def _gen_walls(history: list[dict]) -> list[float]:
    walls, prev = [], 0.0
    for h in history:
        walls.append(h["wall_s"] - prev)
        prev = h["wall_s"]
    return [round(w, 4) for w in walls]


def evaluator_ab(workers: int = 2, generations: int = 4) -> dict:
    """Serial vs parallel vs warm-cache search wall-clock on one workload.

    All three runs use seed 0 in ``static`` fitness mode, so they evaluate
    the *same* variants and reach the same Pareto front — the A/B isolates
    the evaluation engine."""
    import tempfile

    from repro.core.evaluator import (FitnessCache, ParallelEvaluator,
                                      SerialEvaluator)
    from repro.core.search import GevoML
    from repro.workloads.twofc import build_twofc_training_workload

    w = build_twofc_training_workload(batch=32, hidden=64, steps=60,
                                      n_train=2048, n_test=1024)
    cache_path = os.path.join(tempfile.mkdtemp(prefix="gevoml_ab_"),
                              "fitness.jsonl")

    def measure(tag, make_ev):
        ev = make_ev()
        s = GevoML(w, pop_size=10, n_elite=5, seed=0, evaluator=ev)
        t0 = time.perf_counter()
        res = s.run(generations=generations)
        wall = time.perf_counter() - t0
        rec = {"wall_s": round(wall, 4),
               "gen_wall_s": _gen_walls(res.history),
               "n_evals": s.n_evals,
               "cache_hits": s.cache.hits,
               "cache_hit_rate": round(s.cache.hit_rate, 4),
               "pareto": [list(i.fitness) for i in res.pareto]}
        ev.close()
        print(f"[evaluator_ab] {tag}: wall={wall:.2f}s evals={s.n_evals} "
              f"hit_rate={s.cache.hit_rate:.0%}")
        return rec

    out = {
        "workers": workers,
        "generations": generations,
        "serial": measure(
            "serial", lambda: SerialEvaluator(w)),
        "parallel": measure(
            f"parallel x{workers}",
            lambda: ParallelEvaluator(w, n_workers=workers,
                                      cache=FitnessCache(cache_path))),
        # rerun against the persistent cache the parallel run just filled
        "parallel_warm_cache": measure(
            "parallel warm cache",
            lambda: ParallelEvaluator(w, n_workers=workers,
                                      cache=FitnessCache(cache_path))),
    }
    assert out["serial"]["pareto"] == out["parallel"]["pareto"], \
        "parallel evaluation diverged from serial (static mode must match)"
    out["speedup_parallel_vs_serial"] = round(
        out["serial"]["wall_s"] / max(out["parallel"]["wall_s"], 1e-9), 3)
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "evaluator_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[evaluator_ab] wrote {path}; serial/parallel speedup="
          f"{out['speedup_parallel_vs_serial']}x, warm-cache evals="
          f"{out['parallel_warm_cache']['n_evals']}")
    return out


def operators_ab(generations: int = 6) -> dict:
    """Legacy {copy,delete} vs. full five-operator mix on the 2fcNet search.

    Same seed, same budget, ``static`` fitness: the A/B isolates the operator
    mix.  Pareto quality is compared by 2-D hypervolume against a reference
    point slightly worse than the original program's fitness."""
    from repro.core.edits import OperatorWeights
    from repro.core.evaluator import SerialEvaluator
    from repro.core.nsga2 import hypervolume_2d
    from repro.core.search import GevoML
    from repro.workloads.twofc import build_twofc_training_workload

    w = build_twofc_training_workload(batch=32, hidden=64, steps=60,
                                      n_train=2048, n_test=1024)
    to, eo = w.evaluate(w.program)
    ref = (to * 1.05, eo + 0.05)

    def measure(tag, weights):
        ev = SerialEvaluator(w)
        s = GevoML(w, pop_size=12, n_elite=6, seed=0, operators=weights,
                   evaluator=ev)
        t0 = time.perf_counter()
        res = s.run(generations=generations)
        wall = time.perf_counter() - t0
        outcomes = ev.n_evals  # executed variants (cache-missing candidates)
        # candidate validity = mutation proposals that applied cleanly
        # (apply failures are resampled parent-side and never reach the
        # evaluator, so evaluator-level invalids can't measure the mix)
        per_op = res.operator_stats()
        proposed = sum(r["proposed"] for r in per_op.values())
        applied = sum(r["applied"] for r in per_op.values())
        valid_rate = applied / max(proposed, 1)
        hv = hypervolume_2d([i.fitness for i in res.pareto], ref)
        rec = {"operators": list(weights.names()),
               "wall_s": round(wall, 4),
               "n_evals": outcomes,
               "evals_per_s": round(outcomes / max(wall, 1e-9), 2),
               "valid_candidate_rate": round(valid_rate, 4),
               "exec_invalid": ev.n_invalid,
               "pareto": [list(i.fitness) for i in res.pareto],
               "hypervolume": hv,
               "best_error": min(i.fitness[1] for i in res.pareto),
               "best_time": min(i.fitness[0] for i in res.pareto),
               "per_operator": per_op}
        ev.close()
        print(f"[operators_ab] {tag}: valid={valid_rate:.0%} "
              f"evals/s={rec['evals_per_s']} hv={hv:.3e} "
              f"best_err={rec['best_error']:.4f}")
        return rec

    out = {
        "generations": generations,
        "original_fitness": [to, eo],
        "hv_reference": list(ref),
        "legacy": measure("legacy {copy,delete}", OperatorWeights.legacy()),
        "full": measure("full five-operator mix",
                        OperatorWeights.all_registered()),
    }
    out["hv_ratio_full_vs_legacy"] = round(
        out["full"]["hypervolume"] / max(out["legacy"]["hypervolume"], 1e-30),
        3)
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "operators_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[operators_ab] wrote {path}; hypervolume full/legacy="
          f"{out['hv_ratio_full_vs_legacy']}x")
    return out


def kernels_ab(generations: int = 6, seed: int = 0) -> dict:
    """Random-schedule baseline vs GEVO-evolved schedules per Pallas kernel.

    Both arms use the same ``static`` schedule-aware roofline fitness and the
    same evaluation budget (the random arm draws as many unique genomes as
    the evolved search executed), so the A/B isolates the search itself.
    ``best`` arms are the fastest schedule whose numerical error stays within
    the default schedule's error + 1e-3."""
    import numpy as np

    from repro.core.evaluator import SerialEvaluator
    from repro.kernels.workloads import (KERNELS, build_kernel_workload,
                                         evolve_kernel_schedule)

    out: dict = {"generations": generations, "kernels": {}}
    for kernel in KERNELS:
        w = build_kernel_workload(kernel, time_mode="static")

        # distinct patches can decode to the same genome, so the fair budget
        # for the random arm is unique *genomes* the evolved search executed
        genomes_seen: set = set()
        inner_runner = w.runner

        def counting_runner(g, _inner=inner_runner, _seen=genomes_seen):
            _seen.add(tuple(sorted(g.items())))
            return _inner(g)

        w.runner = counting_runner
        ev = SerialEvaluator(w)
        t0 = time.perf_counter()
        s, res, best, within_tol = evolve_kernel_schedule(
            w, generations=generations, seed=seed, evaluator=ev)
        wall = time.perf_counter() - t0
        t_def, e_def = res.original_fitness  # the engine's baseline eval
        tol = e_def + 1e-3
        if not within_tol:
            print(f"[kernels_ab] {kernel}: WARNING no evolved schedule "
                  f"within error tolerance; reporting fastest outright")
        evolved = {
            "wall_s": round(wall, 4),
            "n_evals": ev.n_evals,
            "n_genomes": len(genomes_seen),
            "within_tol": within_tol,
            "cache_hit_rate": round(s.cache.hit_rate, 4),
            "best_time": best.fitness[0],
            "best_error": best.fitness[1],
            "best_schedule": w.space.decode(best.patch.apply(w.program)),
        }

        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        rand_best, seen = None, set()
        budget = min(len(genomes_seen), w.space.size())
        while len(seen) < budget:
            g = w.space.random(rng)
            key = tuple(sorted(g.items()))
            if key in seen:
                continue
            seen.add(key)
            try:
                t, e = w.runner(g)
            except Exception:
                continue
            if e <= tol and (rand_best is None or t < rand_best[0]):
                rand_best = (t, e, g)
        random_arm = {
            "wall_s": round(time.perf_counter() - t0, 4),
            "n_evals": len(seen),
            "best_time": rand_best[0] if rand_best else None,
            "best_error": rand_best[1] if rand_best else None,
            "best_schedule": rand_best[2] if rand_best else None,
        }
        ev.close()

        rec = {"default": {"time": t_def, "error": e_def,
                           "schedule": w.space.decode(w.program)},
               "evolved": evolved, "random": random_arm,
               "evolved_vs_default": round(t_def / evolved["best_time"], 3),
               "evolved_vs_random": (
                   round(random_arm["best_time"] / evolved["best_time"], 3)
                   if rand_best else None)}
        out["kernels"][kernel] = rec
        rand_txt = (f"{random_arm['best_time']:.3e}s"
                    if rand_best else "none-within-tol")
        print(f"[kernels_ab] {kernel}: default={t_def:.3e}s "
              f"evolved={evolved['best_time']:.3e}s "
              f"random={rand_txt} "
              f"speedup_vs_default={rec['evolved_vs_default']}x "
              f"vs_random={rec['evolved_vs_random']}x")

    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "kernels_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[kernels_ab] wrote {path}")
    return out


def islands_ab(generations: int = 6, seed: int = 0) -> dict:
    """1 island vs 4 heterogeneous islands at an equal unique-genome budget
    on the 2fcNet search.

    The islands arm (4 islands × pop 8, heterogeneous operator palette,
    fully-connected migration every 2 generations, one shared persistent
    cache) runs first and sets the budget: the number of unique genomes it
    executed (shared-cache entries — cross-island duplicates count once).
    The baseline is ONE island of the same configuration (pop 8, the
    default "all" mix, same engine) run generation by generation until its
    unique-genome count reaches at least that budget — the single
    population never sees *fewer* genomes than the fleet did.  Pareto
    quality is 2-D hypervolume against a reference slightly worse than the
    original program's fitness."""
    import tempfile

    from repro.core import IslandOrchestrator
    from repro.core.evaluator import SerialEvaluator
    from repro.core.nsga2 import hypervolume_2d
    from repro.core.search import GevoML
    from repro.workloads.twofc import build_twofc_training_workload

    w = build_twofc_training_workload(batch=32, hidden=64, steps=60,
                                      n_train=2048, n_test=1024)
    to, eo = w.evaluate(w.program)
    ref = (to * 1.05, eo + 0.05)
    n_islands, pop_island = 4, 8

    root = tempfile.mkdtemp(prefix="gevoml_islands_ab_")
    orch = IslandOrchestrator(w, root_dir=root, n_islands=n_islands,
                              pop_size=pop_island, migrate_every=2,
                              n_migrants=2, topology="full")
    t0 = time.perf_counter()
    res = orch.run(generations=generations)
    wall_islands = time.perf_counter() - t0
    budget = res.cache_stats["entries"]
    hv_islands = hypervolume_2d([i.fitness for i in res.pareto], ref)
    islands_rec = {
        "n_islands": n_islands, "pop_per_island": pop_island,
        "topology": "full", "migrate_every": 2, "n_migrants": 2,
        "generations": generations,
        "wall_s": round(wall_islands, 4),
        "unique_genomes": budget,
        "migration_rounds": len(res.migration_log),
        "cross_island_hits": res.cross_island_hits,
        "pareto": [list(i.fitness) for i in res.pareto],
        "pareto_sources": res.pareto_sources,
        "hypervolume": hv_islands,
        "per_island": res.cache_stats["per_island"],
    }
    print(f"[islands_ab] islands: {budget} unique genomes, "
          f"hv={hv_islands:.3e}, "
          f"{islands_rec['cross_island_hits']} cross-island hits")

    # -- one-island baseline: run until it has seen >= `budget` genomes ----
    ck = tempfile.mkdtemp(prefix="gevoml_islands_ab_single_")
    ev = SerialEvaluator(w)
    s = GevoML(w, pop_size=pop_island, n_elite=pop_island // 2, seed=seed,
               evaluator=ev, checkpoint_dir=ck)

    class _BudgetReached(Exception):
        pass

    def stop_when_budget(gen, row):
        if len(ev.cache) >= budget:
            raise _BudgetReached

    t0 = time.perf_counter()
    try:
        s.run(generations=generations * 16, on_generation=stop_when_budget)
    except _BudgetReached:
        pass
    wall_single = time.perf_counter() - t0
    last_gen = json.load(open(os.path.join(ck, "latest.json")))["gen"]
    r_single = s.run(generations=last_gen + 1, resume=True)  # no-op replay
    ev.close()
    hv_single = hypervolume_2d([i.fitness for i in r_single.pareto], ref)
    single_rec = {
        "pop_size": pop_island,
        "generations_run": last_gen + 1,
        "wall_s": round(wall_single, 4),
        "unique_genomes": len(ev.cache),
        "pareto": [list(i.fitness) for i in r_single.pareto],
        "hypervolume": hv_single,
    }
    print(f"[islands_ab] single island: {single_rec['unique_genomes']} "
          f"unique genomes over {last_gen + 1} generations, "
          f"hv={hv_single:.3e}")

    out = {
        "generations": generations,
        "original_fitness": [to, eo],
        "hv_reference": list(ref),
        "islands": islands_rec,
        "single": single_rec,
        "hv_ratio_islands_vs_single": round(
            hv_islands / max(hv_single, 1e-30), 3),
    }
    # the acceptance bar for the island orchestrator (see EXPERIMENTS.md):
    # equal-budget heterogeneous islands must not lose to one population,
    # and the shared cache must actually be shared
    assert islands_rec["cross_island_hits"] >= 1, \
        "shared cache reported no cross-island hits"
    assert hv_islands >= hv_single, \
        (f"islands hypervolume {hv_islands:.3e} fell below the "
         f"single-population baseline {hv_single:.3e}")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "islands_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[islands_ab] wrote {path}; hypervolume islands/single="
          f"{out['hv_ratio_islands_vs_single']}x at >= equal budget")
    return out


def serving_ab(generations: int = 2, seed: int = 0,
               artifacts_dir: str = "experiments/artifacts") -> dict:
    """Default serving schedule vs an evolved serving artifact on the
    continuous-batching engine.

    The evolved arm is produced the way a deployment would produce it:
    ``GevoML`` (attr_tweak over the serve schedule space) searches engine
    schedules under *measured* ``(s/token, mean latency)`` fitness on a
    fixed staggered request trace, the fastest Pareto member is exported to
    the :class:`ArtifactRegistry`, and the A/B re-measures both routes from
    a fresh engine with the artifact **resolved back from disk** — the
    GEVO validate-winners-in-the-target-application loop.  Serving latency
    records are published into a shared FitnessCache under the ``serve``
    writer tag alongside the search's own records."""
    import statistics
    import tempfile

    from repro.configs import smoke_config
    from repro.core import GevoML
    from repro.core.deploy import (DEFAULT_ENGINE_SCHEDULE, Artifact,
                                   ArtifactRegistry, ServeEngine,
                                   engine_schedule_from, build_serve_workload)
    from repro.core.evaluator import FitnessCache, SerialEvaluator
    from repro.core.liveloop.traces import demo_requests

    arch = "qwen3-0.6b"
    trace_cfg = dict(n_requests=12, prompt_len=8, gen=8)
    stagger = 4
    w = build_serve_workload(arch, smoke=True, stagger=stagger, seed=seed,
                             **trace_cfg)
    cfg = smoke_config(arch)
    cache_path = os.path.join(tempfile.mkdtemp(prefix="gevoml_serving_ab_"),
                              "fitness.jsonl")

    # -- evolve the serving schedule under measured fitness -----------------
    ev = SerialEvaluator(w, cache=FitnessCache(cache_path, writer="search"))
    s = GevoML(w, pop_size=6, n_elite=3, seed=seed, init_mutations=2,
               mutation_rate=0.9, operators={"attr_tweak": 1.0},
               evaluator=ev)
    t0 = time.perf_counter()
    res = s.run(generations=generations)
    wall_search = time.perf_counter() - t0
    best = res.best_by_time()
    best_genome = w.space.decode(best.patch.apply(w.program))

    # -- ship it: export the winner, resolve it back ------------------------
    registry = ArtifactRegistry(artifacts_dir)
    art_path = registry.export(Artifact(
        kind="serve", name=cfg.name, shape="smoke",
        genome=best_genome, fitness=best.fitness,
        meta={"rule": "min s_per_token (measured)", "trace": trace_cfg,
              "stagger": stagger, "suite": "serving_ab"}))
    resolved = registry.resolve(cfg.name, "smoke", kind="serve")
    evolved_schedule = engine_schedule_from(resolved)

    # -- re-measure both routes from fresh engines --------------------------
    def measure(tag, schedule, publish=False):
        runs = []
        for rep in range(3):
            engine = ServeEngine(cfg, max_len=trace_cfg["prompt_len"]
                                 + trace_cfg["gen"],
                                 max_slots=schedule["max_slots"],
                                 prefill_chunk=schedule["prefill_chunk"])
            engine.run(demo_requests(cfg, seed=seed, **trace_cfg),
                       stagger=stagger)
            stats = engine.stats()
            if publish and rep == 0:
                cache = FitnessCache(cache_path, writer="serve")
                engine.publish_stats(cache, name=cfg.name,
                                     shape={"schedule": tag, **trace_cfg})
                cache.close()
            runs.append(stats)
        med = statistics.median(r["throughput_tok_s"] for r in runs)
        rec = {"schedule": schedule,
               "throughput_tok_s": med,
               "runs_tok_s": [r["throughput_tok_s"] for r in runs],
               "per_variant": runs[0]["per_variant"],
               "decode_batches": runs[0]["decode_batches"]}
        print(f"[serving_ab] {tag}: {schedule} -> {med:.1f} tok/s "
              f"(runs {rec['runs_tok_s']})")
        return rec

    default_rec = measure("default", dict(DEFAULT_ENGINE_SCHEDULE),
                          publish=True)
    evolved_rec = measure("evolved", evolved_schedule, publish=True)
    ev.close()

    n_serve_records = sum(
        1 for line in open(cache_path)
        if json.loads(line).get("writer") == "serve")
    out = {
        "arch": cfg.name, "trace": trace_cfg, "stagger": stagger,
        "generations": generations,
        "search": {"wall_s": round(wall_search, 2), "n_evals": s.n_evals,
                   "space_size": w.space.size(),
                   "best_genome": best_genome,
                   "best_fitness": list(best.fitness),
                   "default_fitness": list(res.original_fitness)},
        "artifact": {"path": art_path,
                     "fingerprint": resolved.fingerprint()},
        "default": default_rec,
        "evolved": evolved_rec,
        "throughput_ratio_evolved_vs_default": round(
            evolved_rec["throughput_tok_s"]
            / max(default_rec["throughput_tok_s"], 1e-9), 3),
        "serve_cache_records": n_serve_records,
    }
    # the acceptance bar: the evolved-artifact route must not lose to the
    # default schedule on the trace it was evolved for, and serving must
    # have fed latency records back into the shared cache
    assert n_serve_records >= 2, "no serve-tagged records in the cache"
    assert out["throughput_ratio_evolved_vs_default"] >= 1.0, \
        (f"evolved serving artifact lost to the default schedule "
         f"({evolved_rec['throughput_tok_s']:.1f} vs "
         f"{default_rec['throughput_tok_s']:.1f} tok/s)")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "serving_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[serving_ab] wrote {path}; evolved/default throughput="
          f"{out['throughput_ratio_evolved_vs_default']}x "
          f"({n_serve_records} serve-tagged cache records)")
    return out


def tensor_evo_ab(seed: int = 0, pop: int = 1024,
                  throughput_gens: int = 8) -> dict:
    """The tensorized on-device engine vs the Python engine on the joint
    three-kernel schedule space, plus the islands-vs-panmictic A/B rerun at
    >= 100x the PR-4 genome budget.

    Throughput arm: ``TensorGevoML`` (pop 1024) computes fitness for every
    population lane in one jitted array program per generation;
    ``GevoML(engine="python")`` evaluates per genome through the serial
    evaluator (memoized, so its metric counts *executed* evaluations —
    the favorable accounting for the Python arm).  Both numbers are
    fitness-assignments/sec on the same workload.

    Budget arm: 4 mesh islands x pop 1024 x 4 generations = 16384
    genome-evals (PR 4's islands_ab executed 140 unique genomes, so this is
    >= 100x that budget) vs one panmictic tensor population of 4096 at the
    same generation count.  Pareto quality is 2-D hypervolume against a
    reference slightly worse than the default schedule's fitness."""
    import tempfile

    from repro.core.nsga2 import hypervolume_2d
    from repro.core.search import GevoML
    from repro.core.tensor_evo import TensorGevoML, TensorIslandFleet
    from repro.kernels.workloads import build_joint_kernel_workload

    w = build_joint_kernel_workload()
    to, eo = w.evaluate(w.program)
    ref = (to * 1.05, eo + 0.05)

    # -- throughput: population-evals/sec, tensor vs python engine ---------
    t0 = time.perf_counter()
    eng = TensorGevoML(w, pop_size=pop, n_elite=32, seed=seed)
    res_t = eng.run(generations=throughput_gens, record_cache=False)
    wall_t = time.perf_counter() - t0
    evals_t = res_t.history[-1]["evals"]
    tensor_rec = {
        "pop_size": pop, "generations": throughput_gens,
        "wall_s": round(wall_t, 4), "population_evals": evals_t,
        "evals_per_s": round(evals_t / max(wall_t, 1e-9), 2),
        "pareto": sorted(list(i.fitness) for i in res_t.pareto),
        "hypervolume": hypervolume_2d(
            [i.fitness for i in res_t.pareto], ref),
    }
    print(f"[tensor_evo_ab] tensor engine: {evals_t} population-evals in "
          f"{wall_t:.2f}s = {tensor_rec['evals_per_s']}/s")

    py_pop, py_gens = 64, 2
    s = GevoML(w, engine="python", pop_size=py_pop, n_elite=16, seed=seed,
               operators={"attr_tweak": 1.0})
    t0 = time.perf_counter()
    res_p = s.run(generations=py_gens)
    wall_p = time.perf_counter() - t0
    python_rec = {
        "pop_size": py_pop, "generations": py_gens,
        "wall_s": round(wall_p, 4), "executed_evals": s.n_evals,
        "evals_per_s": round(s.n_evals / max(wall_p, 1e-9), 2),
        "hypervolume": hypervolume_2d(
            [i.fitness for i in res_p.pareto], ref),
    }
    print(f"[tensor_evo_ab] python engine: {s.n_evals} executed evals in "
          f"{wall_p:.2f}s = {python_rec['evals_per_s']}/s")
    speedup = round(tensor_rec["evals_per_s"]
                    / max(python_rec["evals_per_s"], 1e-9), 2)

    # -- 100x-budget islands vs panmictic at equal lane budget -------------
    n_isl, ipop, igens = 4, pop, 4
    genome_evals = n_isl * ipop * igens
    root = tempfile.mkdtemp(prefix="tensor_islands_ab_")
    t0 = time.perf_counter()
    with TensorIslandFleet(w, root_dir=root, n_islands=n_isl, pop_size=ipop,
                           n_elite=32, migrate_every=2, n_migrants=8,
                           topology="full", seed=seed) as fleet:
        res_i = fleet.run(igens)
    wall_i = time.perf_counter() - t0
    hv_islands = hypervolume_2d([i.fitness for i in res_i.pareto], ref)
    islands_rec = {
        "n_islands": n_isl, "pop_per_island": ipop, "generations": igens,
        "topology": "full", "migrate_every": 2, "n_migrants": 8,
        "wall_s": round(wall_i, 4),
        "genome_evals": genome_evals,
        "unique_genomes": res_i.cache_stats["entries"],
        "migration_rounds": len(res_i.migration_log),
        "cross_island_hits": res_i.cross_island_hits,
        "writer_tags": res_i.cache_stats["writer_tags"],
        "hypervolume": hv_islands,
    }
    print(f"[tensor_evo_ab] mesh islands: {genome_evals} genome-evals "
          f"({islands_rec['unique_genomes']} unique) in {wall_i:.2f}s, "
          f"hv={hv_islands:.3e}, "
          f"{islands_rec['cross_island_hits']} cross-island hits")

    t0 = time.perf_counter()
    pan = TensorGevoML(w, pop_size=n_isl * ipop, n_elite=32, seed=seed)
    res_pan = pan.run(generations=igens, record_cache=False)
    wall_pan = time.perf_counter() - t0
    hv_pan = hypervolume_2d([i.fitness for i in res_pan.pareto], ref)
    pan_rec = {
        "pop_size": n_isl * ipop, "generations": igens,
        "wall_s": round(wall_pan, 4),
        "genome_evals": res_pan.history[-1]["evals"],
        "hypervolume": hv_pan,
    }
    print(f"[tensor_evo_ab] panmictic: {pan_rec['genome_evals']} "
          f"genome-evals in {wall_pan:.2f}s, hv={hv_pan:.3e}")

    out = {
        "workload": w.name,
        "space_size": w.space.size(),
        "original_fitness": [to, eo],
        "hv_reference": list(ref),
        "tensor": tensor_rec,
        "python": python_rec,
        "speedup_tensor_vs_python": speedup,
        "pr4_genome_budget": 140,
        "budget_ratio_vs_pr4": round(genome_evals / 140, 1),
        "islands": islands_rec,
        "panmictic": pan_rec,
        "hv_ratio_islands_vs_panmictic": round(
            hv_islands / max(hv_pan, 1e-30), 3),
    }
    # the acceptance bars (see ISSUE/EXPERIMENTS.md): the tensorized engine
    # must clear 10x the Python engine's eval throughput, the budget must be
    # >= 100x PR 4's 140-genome islands_ab, and the mesh fleet's shared
    # cache must actually be shared
    assert speedup >= 10, \
        f"tensor engine speedup {speedup}x fell below the 10x bar"
    assert genome_evals >= 14000, \
        f"budget {genome_evals} below 100x the PR-4 run (14000)"
    assert islands_rec["cross_island_hits"] >= 1, \
        "mesh shared cache reported no cross-island hits"
    assert hv_islands >= 0.99 * hv_pan, \
        (f"mesh islands hypervolume {hv_islands:.3e} fell below the "
         f"panmictic baseline {hv_pan:.3e}")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "tensor_evo_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[tensor_evo_ab] wrote {path}; tensor/python throughput="
          f"{speedup}x, islands/panmictic hv="
          f"{out['hv_ratio_islands_vs_panmictic']}x at "
          f"{out['budget_ratio_vs_pr4']}x the PR-4 budget")
    return out


def analysis_ab(generations: int = 12, seed: int = 0) -> dict:
    """Screened vs unscreened ``GevoML`` — same seed, same budget, byte-
    identical exported Pareto fronts; the A/B isolates the static screen.

    Two searches: the 2fcNet IR search (program screen: DCE + constant
    folding + canonical fingerprints) and the joint three-kernel schedule
    search (kernel screen: decode + launch gates + genome canon).  Both run
    in ``static`` fitness mode, where verdict inheritance is exact, so the
    screened arm must reproduce the unscreened arm's front byte for byte
    while skipping the executions the screen resolved."""
    import tempfile

    from repro.core.evaluator import SerialEvaluator
    from repro.core.search import GevoML
    from repro.kernels.workloads import build_joint_kernel_workload
    from repro.workloads.twofc import build_twofc_training_workload

    root = tempfile.mkdtemp(prefix="gevoml_analysis_ab_")

    def arm(tag, workload, *, screen, gens, **gevo_kw):
        ev = SerialEvaluator(workload)
        s = GevoML(workload, seed=seed, evaluator=ev, screen=screen,
                   **gevo_kw)
        t0 = time.perf_counter()
        res = s.run(generations=gens)
        wall = time.perf_counter() - t0
        front_path = os.path.join(root, f"{tag}.json")
        res.export_front(front_path)
        st = ev.stats()
        rec = {"wall_s": round(wall, 4),
               "n_evals": st["n_evals"],
               "n_screened": st["n_screened"],
               "screened_by": st["screened_by"],
               "pareto": sorted(list(i.fitness) for i in res.pareto),
               "population": [list(i.fitness) for i in res.population],
               "per_operator": res.operator_stats()}
        ev.close()
        return rec, front_path

    out: dict = {"generations": generations, "seed": seed, "searches": {}}
    searches = {
        "twofc": (build_twofc_training_workload(
                      batch=32, hidden=16, steps=5,
                      n_train=256, n_test=200),
                  dict(pop_size=10, n_elite=5)),
        "joint_kernels": (build_joint_kernel_workload(),
                          dict(pop_size=10, n_elite=5, init_mutations=2,
                               mutation_rate=0.9,
                               operators={"attr_tweak": 1.0})),
    }
    tot_screened = tot_missed = 0
    for name, (w, kw) in searches.items():
        base, base_front = arm(f"{name}_unscreened", w, screen=False,
                               gens=generations, **kw)
        scr, scr_front = arm(f"{name}_screened", w, screen=True,
                             gens=generations, **kw)
        front_equal = (open(base_front, "rb").read()
                       == open(scr_front, "rb").read())
        # the bit-exactness bar: identical exported front BYTES and
        # identical final population fitness, at the same genome budget
        assert front_equal, \
            f"{name}: screened front diverged from unscreened"
        assert base["population"] == scr["population"], \
            f"{name}: screened population fitness diverged"
        missed = scr["n_evals"] + scr["n_screened"]
        skip = scr["n_screened"] / max(missed, 1)
        tot_screened += scr["n_screened"]
        tot_missed += missed
        out["searches"][name] = {
            "unscreened": {k: base[k] for k in
                           ("wall_s", "n_evals", "pareto")},
            "screened": {k: scr[k] for k in
                         ("wall_s", "n_evals", "n_screened", "screened_by",
                          "pareto", "per_operator")},
            "front_bytes_equal": front_equal,
            "executions_skipped": base["n_evals"] - scr["n_evals"],
            "skip_rate": round(skip, 4),
        }
        print(f"[analysis_ab] {name}: fronts byte-equal; "
              f"{base['n_evals']} evals unscreened vs {scr['n_evals']} "
              f"screened ({scr['n_screened']} resolved statically, "
              f"skip rate {skip:.0%}, verdicts {scr['screened_by']})")
    out["skip_rate_overall"] = round(tot_screened / max(tot_missed, 1), 4)
    # the acceptance bar (see ISSUE/EXPERIMENTS.md): fronts byte-identical
    # (asserted above) and >= 20% of proposed cache-missing mutants
    # resolved without execution
    assert out["skip_rate_overall"] >= 0.20, \
        (f"static screen resolved only {out['skip_rate_overall']:.0%} of "
         f"cache-missing mutants (bar: 20%)")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "analysis_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[analysis_ab] wrote {path}; fronts byte-identical, overall "
          f"skip rate {out['skip_rate_overall']:.0%}")
    return out


def surrogate_ab(generations: int = 10, seed: int = 5,
                 keep: float = 0.5) -> dict:
    """Surrogate-guided vs unguided ``GevoML`` on the joint three-kernel
    schedule search — same seed, same genome budget.  The guided arm
    generates offspring at the normal rate, featurizes each cache-missing
    candidate (schedule one-hots + roofline/VMEM counters), and lets the
    ridge cost model trained from the run's own FitnessCache pick the
    predicted-Pareto slice that actually reaches the evaluator.  The bar
    (see ISSUE/EXPERIMENTS.md): guided hypervolume >= 1.0x unguided while
    executing <= 70% of the unguided arm's evaluations.

    The feature probes' VMEM/roofline numbers depend on the XLA host
    device count, so the whole suite runs under
    :func:`pinned_xla_host_devices` — order-independent of whatever suite
    ran (and initialized jax) before it."""
    with pinned_xla_host_devices(512):
        return _surrogate_ab_body(generations, seed, keep)


def _surrogate_ab_body(generations: int, seed: int, keep: float) -> dict:
    from repro.core.evaluator import SerialEvaluator
    from repro.core.nsga2 import hypervolume_2d
    from repro.core.search import GevoML
    from repro.kernels.workloads import build_joint_kernel_workload

    w = build_joint_kernel_workload()
    to, eo = w.evaluate(w.program)
    ref = (to * 1.05, eo + 0.05)
    kw = dict(pop_size=10, n_elite=5, init_mutations=2, mutation_rate=0.9,
              operators={"attr_tweak": 1.0})

    def arm(tag, *, surrogate):
        ev = SerialEvaluator(w)
        s = GevoML(w, seed=seed, evaluator=ev, surrogate=surrogate,
                   surrogate_keep=keep, **kw)
        t0 = time.perf_counter()
        res = s.run(generations=generations)
        wall = time.perf_counter() - t0
        rec = {"wall_s": round(wall, 4),
               "executed_evals": ev.stats()["n_evals"],
               "hypervolume": hypervolume_2d(
                   [i.fitness for i in res.pareto], ref),
               "pareto": sorted(list(i.fitness) for i in res.pareto)}
        if surrogate:
            rec["surrogate"] = s.guide.stats()
            rec["per_operator"] = res.operator_stats()
        ev.close()
        print(f"[surrogate_ab] {tag}: {rec['executed_evals']} executed "
              f"evals, hypervolume {rec['hypervolume']:.3e}")
        return rec

    base = arm("unguided", surrogate=False)
    guided = arm("guided", surrogate=True)
    hv_ratio = guided["hypervolume"] / max(base["hypervolume"], 1e-30)
    exec_frac = guided["executed_evals"] / max(base["executed_evals"], 1)
    out = {"generations": generations, "seed": seed, "keep": keep,
           "ref_point": list(ref),
           "unguided": base, "guided": guided,
           "hv_ratio_guided_vs_unguided": round(hv_ratio, 4),
           "executed_frac_guided_vs_unguided": round(exec_frac, 4)}
    # the acceptance bar: no Pareto-quality regression at a real
    # execution saving
    assert hv_ratio >= 1.0, \
        (f"guided hypervolume fell to {hv_ratio:.3f}x unguided "
         f"(bar: >= 1.0x)")
    assert exec_frac <= 0.70, \
        (f"guided arm executed {exec_frac:.0%} of the unguided arm's "
         f"evaluations (bar: <= 70%)")
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "surrogate_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[surrogate_ab] wrote {path}; hypervolume guided/unguided="
          f"{hv_ratio:.2f}x at {exec_frac:.0%} of the executions")
    return out


def liveloop_ab(ticks: int = 3, seed: int = 0) -> dict:
    """The full live loop, both exits of the state machine.

    **Promote arm** (real engine): a :class:`LiveLoopController` in
    ``mode="real"`` evolves the serve schedule against a synthesized
    bursty trace replayed through actual :class:`ServeEngine` instances,
    canaries the winner by shadow-replaying a deterministic trace slice
    under both schedules, and promotes it through the journaled
    guardrails.  The promoted artifact is then
    re-measured from scratch (median of 3 full-trace replays) against the
    default schedule — the bar is throughput >= 1.0x default.

    **Rollback arm** (modeled, fault-injected): the same trace under the
    deterministic engine model, with a fault hook tripling every canary
    measurement's latency — the guardrails must roll the candidate back,
    block its fingerprint, and never re-propose it."""
    import statistics
    import tempfile

    from repro.configs import smoke_config
    from repro.core.deploy import DEFAULT_ENGINE_SCHEDULE, ServeEngine
    from repro.core.liveloop import (Guardrails, LiveLoopController, replay,
                                     synthesize)

    arch = "qwen3-0.6b"
    cfg = smoke_config(arch)
    trace = synthesize("bursty", vocab=cfg.vocab, n_requests=10,
                       max_prompt=8, gen=6, seed=seed)
    print(f"[liveloop_ab] trace: {trace.summary()}")

    # -- promote arm: real measured loop ------------------------------------
    root = tempfile.mkdtemp(prefix="liveloop_ab_")
    # pop 10 over the 12-point schedule space all but enumerates it, and
    # the canary gate tolerates 5% run-to-run measurement noise (both
    # sides shadow-replay the same slice, so there is no cross-slice
    # composition noise) -- the hard >= 1.0x bar is the from-scratch
    # re-measure below
    ctl = LiveLoopController(root, trace=trace, arch=arch, mode="real",
                             gens_per_tick=2, pop=10, seed=seed,
                             fraction=0.5,
                             guardrails=Guardrails(
                                 min_throughput_ratio=0.95, windows=2))
    t0 = time.perf_counter()
    summaries = ctl.run(ticks)
    wall_loop = time.perf_counter() - t0
    for s in summaries:
        print(f"[liveloop_ab] tick {s['tick']}: cand={s['candidate']} "
              f"outcome={s['outcome'] or 'pending'}")
    promoted = ctl.book.promoted
    assert promoted is not None, \
        f"no promotion after {ticks} ticks: {ctl.book.status()}"
    live = ctl.registry.resolve(arch, "live", kind="serve")
    assert live is not None and live.genome == promoted["genome"], \
        "registry live pointer does not match the journaled promotion"

    # -- re-measure the promoted schedule from scratch ----------------------
    params = ctl._model()[1]

    def measure(schedule):
        runs = []
        for i in range(4):
            engine = ServeEngine(cfg, params, max_len=trace.max_len(),
                                 max_slots=schedule["max_slots"],
                                 prefill_chunk=schedule["prefill_chunk"])
            replay(engine, trace)
            if i == 0:      # unmeasured warmup: XLA compiles stay out
                continue
            runs.append(engine.stats()["throughput_tok_s"])
        return statistics.median(runs), runs

    thr_default, runs_default = measure(dict(DEFAULT_ENGINE_SCHEDULE))
    thr_live, runs_live = measure(dict(live.genome))
    ratio = round(thr_live / max(thr_default, 1e-9), 3)
    print(f"[liveloop_ab] default {thr_default:.1f} tok/s vs promoted "
          f"{thr_live:.1f} tok/s -> {ratio}x")

    # -- rollback arm: fault-injected modeled loop --------------------------
    def fault(genome, metrics):
        m = dict(metrics)
        m["throughput_tok_s"] = round(m["throughput_tok_s"] / 3.0, 6)
        m["mean_ttft_s"] = round(m["mean_ttft_s"] * 3.0, 6)
        m["mean_latency_s"] = round(m["mean_latency_s"] * 3.0, 6)
        return m

    root_rb = tempfile.mkdtemp(prefix="liveloop_ab_rb_")
    ctl_rb = LiveLoopController(root_rb, trace=trace, arch=arch,
                                mode="modeled", gens_per_tick=1, pop=6,
                                seed=seed, fraction=0.5,
                                guardrails=Guardrails(windows=2),
                                fault_hook=fault)
    rb_summaries = ctl_rb.run(ticks + 1)
    rb_outcomes = [s["outcome"] for s in rb_summaries]
    blocked = ctl_rb.book.status()["blocked"]
    print(f"[liveloop_ab] rollback arm outcomes: {rb_outcomes}, "
          f"blocked={[(b[:12] + '…') for b in blocked]}")
    # the blocklist invariant: once a fingerprint rolls back, it is never
    # proposed again (fresh fingerprints may still be — each new genome
    # gets its one canary before the fault hook sinks it)
    rolled = set()
    re_proposed = False
    for ev in ctl_rb.book.doc["history"]:
        if ev["event"] == "rollback":
            rolled.add(ev["fingerprint"])
        elif ev["event"] == "propose" and ev["fingerprint"] in rolled:
            re_proposed = True

    out = {
        "arch": arch, "trace": trace.summary(), "ticks": ticks,
        "loop_wall_s": round(wall_loop, 2),
        "promote": {
            "summaries": summaries,
            "promoted_genome": promoted["genome"],
            "canary_ratios": promoted["ratios"],
            "default_tok_s": {"median": thr_default, "runs": runs_default},
            "promoted_tok_s": {"median": thr_live, "runs": runs_live},
            "throughput_ratio_promoted_vs_default": ratio,
        },
        "rollback": {
            "outcomes": rb_outcomes,
            "blocked": blocked,
            "re_proposed_after_rollback": re_proposed,
        },
        "serve_cache_records": sum(
            1 for line in open(os.path.join(root, "cache.jsonl"))
            if json.loads(line).get("writer") == "serve"),
    }
    # acceptance bars: the loop promotes a genome that measures no worse
    # than the default artifact; the fault-injected run is rolled back and
    # its fingerprint is never re-canaried
    assert ratio >= 1.0, \
        (f"promoted serve genome lost to the default schedule "
         f"({thr_live:.1f} vs {thr_default:.1f} tok/s)")
    assert "rolled_back" in rb_outcomes, \
        f"fault-injected run was not rolled back: {rb_outcomes}"
    assert len(blocked) >= 1, "rollback did not block the fingerprint"
    assert not out["rollback"]["re_proposed_after_rollback"], \
        "a rolled-back fingerprint was re-proposed"
    assert out["serve_cache_records"] >= 2, \
        "the loop published no serve-tagged records"
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "liveloop_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[liveloop_ab] wrote {path}; promoted/default throughput="
          f"{ratio}x, rollback arm blocked "
          f"{len(blocked)} fingerprint(s)")
    return out


def sharded_serving_ab(generations: int = 4, seed: int = 0,
                       artifacts_dir: str = "experiments/artifacts") -> dict:
    """Default serve plan vs an evolved FULL plan (slots x prefill chunk x
    KV page size x cache dtype x replica layout) on the multi-replica
    router.

    ``GevoML`` (attr_tweak over the joint :data:`SERVE_SPACE`) searches the
    432-point plan space under a deterministic two-objective fitness:
    modeled s/token from the live loop's discrete-event serving model
    (``liveloop.simulate``, replica- and byte-budget-aware) against the
    *measured* quantized-cache decode error (memoized per
    ``(kv_dtype, kv_page_size)`` — the model forward is plan-independent).
    The deployment rule is the KV-plan fitness gate as code:
    ``front.select("time", on="error", limit=KV_ERROR_GATE)``.  The winner
    ships through the ArtifactRegistry, resolves back from disk, and is
    re-measured as a real :class:`Router` (warmup + median of 3 full-trace
    replays) against the default plan.  A second bar replays the same plan
    at its replica fan-out vs pinned to one replica over the same smoke
    mesh — the router must not lose to a single replica."""
    import statistics
    import tempfile

    import jax
    import numpy as np

    from repro.configs import smoke_config
    from repro.core import GevoML
    from repro.core.deploy import (DEFAULT_SERVE_PLAN, KV_ERROR_GATE,
                                   Artifact, ArtifactRegistry, KVPlan,
                                   build_router, measure_cache_error,
                                   serve_plan_from, serve_schedule_space)
    from repro.core.evaluator import FitnessCache, SerialEvaluator
    from repro.core.fitness import KernelWorkload
    from repro.core.liveloop import replay, synthesize
    from repro.core.liveloop.controller import simulate
    from repro.core.serialize import patch_from_doc
    from repro.launch.mesh import make_smoke_mesh
    from repro.models.transformer import init_params

    arch = "qwen3-0.6b"
    cfg = smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    # slot-starved regime: enough concurrent requests that the default
    # 2-slot plan queues heavily, so residency/replica knobs matter
    trace = synthesize("bursty", vocab=cfg.vocab, n_requests=24,
                       max_prompt=8, gen=8, seed=seed)
    max_len = trace.max_len()
    print(f"[sharded_serving_ab] trace: {trace.summary()}")

    # -- evolve the full serving plan under the error-gated fitness ---------
    space = serve_schedule_space(arch)
    probe = np.asarray(
        np.random.default_rng(seed).integers(1, cfg.vocab, size=(2, 8)))
    err_memo: dict[tuple, float] = {}

    def plan_error(genome: dict) -> float:
        plan = KVPlan.from_genome(genome)
        key = (plan.dtype, plan.page_size)
        if key not in err_memo:
            err_memo[key] = measure_cache_error(
                cfg, params, plan, probe)["measured"]
        return err_memo[key]

    def runner(genome: dict) -> tuple[float, float]:
        return (simulate(trace, genome)["s_per_token"], plan_error(genome))

    w = KernelWorkload(name=f"serve/{arch}",
                       program=space.encode(DEFAULT_SERVE_PLAN),
                       space=space, runner=runner, time_mode="static",
                       kind="serve")
    cache_path = os.path.join(
        tempfile.mkdtemp(prefix="gevoml_sharded_serving_ab_"),
        "fitness.jsonl")
    ev = SerialEvaluator(w, cache=FitnessCache(cache_path, writer="search"))
    s = GevoML(w, pop_size=8, n_elite=4, seed=seed, init_mutations=2,
               mutation_rate=0.9, operators={"attr_tweak": 1.0},
               evaluator=ev)
    t0 = time.perf_counter()
    res = s.run(generations=generations)
    wall_search = time.perf_counter() - t0
    ev.close()

    # the deployment rule: fastest modeled plan whose measured decode error
    # clears the KV fitness gate
    front = res.to_front(origin="sharded_serving_ab")
    member = front.select("time", on="error", limit=KV_ERROR_GATE)
    best_genome = w.space.decode(
        patch_from_doc(list(member.patch)).apply(w.program))
    sim_default = simulate(trace, dict(DEFAULT_SERVE_PLAN))
    sim_evolved = simulate(trace, best_genome)
    modeled_ratio = round(sim_evolved["throughput_tok_s"]
                          / max(sim_default["throughput_tok_s"], 1e-9), 3)
    print(f"[sharded_serving_ab] selected plan {best_genome} "
          f"(error {member.fitness[1]:.4g} <= gate {KV_ERROR_GATE}); "
          f"modeled evolved/default throughput={modeled_ratio}x")

    # -- ship it: export the winner, resolve it back ------------------------
    registry = ArtifactRegistry(artifacts_dir)
    art_path = registry.export(Artifact(
        kind="serve", name=cfg.name, shape="sharded_smoke",
        genome=best_genome, fitness=member.fitness,
        meta={"rule": f"min modeled s/token s.t. "
                      f"cache error <= {KV_ERROR_GATE}",
              "trace": trace.summary(), "suite": "sharded_serving_ab"}))
    resolved = registry.resolve(cfg.name, "sharded_smoke", kind="serve")
    evolved_plan = serve_plan_from(resolved)

    # -- re-measure real routers from scratch on one smoke mesh -------------
    # every arm runs on the SAME mesh: replicas split its data rows into
    # submeshes (params + caches sharded per row group), a 1-replica plan
    # owns the whole mesh — the honest apples-to-apples for a plan whose
    # replica knob means "parallel hardware"
    multi_plan = dict(evolved_plan)
    if int(multi_plan["replicas"]) < 2:
        multi_plan["replicas"] = 2
    single_plan = dict(multi_plan, replicas=1)
    mesh = make_smoke_mesh(int(multi_plan["replicas"]), 2)

    def measure(tag, plan_genome, *, mesh=None, publish=False):
        runs, stats = [], None
        for rep in range(4):
            router = build_router(cfg, params, genome=plan_genome,
                                  max_len=max_len, mesh=mesh, seed=seed)
            report = replay(router, trace)
            assert report.n_rejected == 0 and \
                len(report.results) == len(trace.items), \
                f"{tag}: replay dropped requests"
            stats = router.stats()
            if rep == 0:        # unmeasured warmup: XLA compiles stay out
                if publish:
                    cache = FitnessCache(cache_path, writer="serve")
                    router.publish_stats(cache, name=cfg.name,
                                         shape={"plan": tag,
                                                "trace": trace.summary()})
                    cache.close()
                continue
            runs.append(stats["throughput_tok_s"])
        med = statistics.median(runs)
        rec = {"plan": dict(plan_genome), "throughput_tok_s": med,
               "runs_tok_s": runs, "n_replicas": stats["n_replicas"],
               "effective_slots": router.replicas[0].engine.max_slots,
               "on_mesh": mesh is not None}
        print(f"[sharded_serving_ab] {tag}: replicas="
              f"{stats['n_replicas']} -> {med:.1f} tok/s (runs {runs})")
        return rec

    default_rec = measure("default", dict(DEFAULT_SERVE_PLAN), mesh=mesh,
                          publish=True)
    evolved_rec = measure("evolved", evolved_plan, mesh=mesh, publish=True)
    plan_ratio = round(evolved_rec["throughput_tok_s"]
                       / max(default_rec["throughput_tok_s"], 1e-9), 3)

    # -- router vs a single replica of the same plan ------------------------
    router_rec = measure("router", multi_plan, mesh=mesh)
    single_rec = measure("single", single_plan, mesh=mesh)
    router_ratio = round(router_rec["throughput_tok_s"]
                         / max(single_rec["throughput_tok_s"], 1e-9), 3)

    n_serve_records = sum(
        1 for line in open(cache_path)
        if json.loads(line).get("writer") == "serve")
    out = {
        "arch": cfg.name, "trace": trace.summary(),
        "generations": generations,
        "search": {"wall_s": round(wall_search, 2), "n_evals": s.n_evals,
                   "space_size": space.size(),
                   "selected_genome": best_genome,
                   "selected_fitness": list(member.fitness),
                   "error_gate": KV_ERROR_GATE,
                   "default_fitness": list(res.original_fitness),
                   "front_size": len(front.members),
                   "measured_cache_errors": {
                       f"{k[0]}/p{k[1]}": round(v, 6)
                       for k, v in sorted(err_memo.items())}},
        "modeled_ratio_evolved_vs_default": modeled_ratio,
        "artifact": {"path": art_path,
                     "fingerprint": resolved.fingerprint()},
        "default": default_rec,
        "evolved": evolved_rec,
        "throughput_ratio_evolved_vs_default": plan_ratio,
        "router_on_mesh": router_rec,
        "single_on_mesh": single_rec,
        "throughput_ratio_router_vs_single": router_ratio,
        "serve_cache_records": n_serve_records,
    }
    # acceptance bars: the gate-feasible evolved plan must not lose to the
    # default plan (modeled and real), the replica fan-out must not lose to
    # one replica of the same plan on the smoke mesh, and both router
    # measurements must have fed serve-tagged records back into the cache
    assert member.fitness[1] <= KV_ERROR_GATE, \
        "select() returned a plan outside the decode-error gate"
    assert modeled_ratio >= 1.0, \
        f"evolved plan lost to the default under the model ({modeled_ratio}x)"
    assert plan_ratio >= 1.0, \
        (f"evolved serve plan lost to the default plan "
         f"({evolved_rec['throughput_tok_s']:.1f} vs "
         f"{default_rec['throughput_tok_s']:.1f} tok/s)")
    assert router_ratio >= 1.0, \
        (f"router lost to a single replica of the same plan "
         f"({router_rec['throughput_tok_s']:.1f} vs "
         f"{single_rec['throughput_tok_s']:.1f} tok/s)")
    assert n_serve_records >= 2, "no serve-tagged records in the cache"
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, "sharded_serving_ab.json")
    json.dump(out, open(path, "w"), indent=1)
    print(f"[sharded_serving_ab] wrote {path}; evolved/default="
          f"{plan_ratio}x, router/single={router_ratio}x "
          f"({n_serve_records} serve-tagged cache records)")
    return out


def run_cells():
    os.makedirs(OUT, exist_ok=True)

    # ---- cell A: zamba2-1.2b train_4k (worst roofline fraction) ----------
    z = get_config("zamba2-1.2b")
    run("zamba2_train_0_baseline", "zamba2-1.2b", "train_4k",
        z.scaled(ssm_impl="naive"))
    run("zamba2_train_1_ssd", "zamba2-1.2b", "train_4k", z)  # ssd default
    run("zamba2_train_2_ssd_blockattn_remat", "zamba2-1.2b", "train_4k",
        z.scaled(attn_impl="blockwise", attn_block=512, remat="full"))
    run("zamba2_train_3_plus_losschunk", "zamba2-1.2b", "train_4k",
        z.scaled(attn_impl="blockwise", attn_block=512, remat="full",
                 loss_chunk=512))

    # ---- cell B: deepseek-v3-671b train_4k (most collective-bound) -------
    d = get_config("deepseek-v3-671b")
    run("deepseek_train_0_baseline", "deepseek-v3-671b", "train_4k",
        d.scaled(gnorm_vdot=True))
    run("deepseek_train_1_sharded_gnorm", "deepseek-v3-671b", "train_4k", d)
    run("deepseek_train_2_blockattn", "deepseek-v3-671b", "train_4k",
        d.scaled(attn_impl="blockwise", attn_block=512))
    run("deepseek_train_3_plus_losschunk", "deepseek-v3-671b", "train_4k",
        d.scaled(attn_impl="blockwise", attn_block=512, loss_chunk=512))

    # ---- cell C: qwen2-vl-72b prefill_32k (attention-memory-bound) -------
    q = get_config("qwen2-vl-72b")
    run("qwen2vl_prefill_0_baseline", "qwen2-vl-72b", "prefill_32k", q)
    run("qwen2vl_prefill_1_blockattn", "qwen2-vl-72b", "prefill_32k",
        q.scaled(attn_impl="blockwise", attn_block=512))
    run("qwen2vl_prefill_2_blockattn1k", "qwen2-vl-72b", "prefill_32k",
        q.scaled(attn_impl="blockwise", attn_block=1024))
    run("qwen2vl_prefill_3_nofsdp", "qwen2-vl-72b", "prefill_32k",
        q.scaled(attn_impl="blockwise", attn_block=512, fsdp=False))

    # ---- bonus D: falcon-mamba-7b train_4k (worst memory after resweep) ---
    f = get_config("falcon-mamba-7b")
    run("falcon_train_0_baseline", "falcon-mamba-7b", "train_4k",
        f.scaled(ssm_impl="naive"))
    run("falcon_train_1_chunked", "falcon-mamba-7b", "train_4k", f)
    run("falcon_train_2_chunked_remat", "falcon-mamba-7b", "train_4k",
        f.scaled(remat="full"))

    # ---- bonus E: deepseek-v3-671b decode_32k (weight-gather collectives) -
    run("deepseek_decode_0_gather", "deepseek-v3-671b", "decode_32k",
        d.scaled(moe_mode="dense"))
    run("deepseek_decode_1_ep_a2a", "deepseek-v3-671b", "decode_32k", d)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite",
                    choices=("cells", "evaluator", "operators", "kernels",
                             "islands", "serving", "tensor_evo", "analysis",
                             "surrogate", "liveloop", "sharded_serving",
                             "all"),
                    default="cells")
    ap.add_argument("--workers", type=int, default=2,
                    help="ParallelEvaluator workers for --suite evaluator")
    ap.add_argument("--generations", type=int, default=4)
    args = ap.parse_args()
    if args.suite in ("cells", "all"):
        run_cells()
    if args.suite in ("evaluator", "all"):
        evaluator_ab(workers=args.workers, generations=args.generations)
    if args.suite in ("operators", "all"):
        operators_ab(generations=max(args.generations, 6))
    if args.suite in ("kernels", "all"):
        kernels_ab(generations=max(args.generations, 6))
    if args.suite in ("islands", "all"):
        islands_ab(generations=max(args.generations, 6))
    if args.suite in ("serving", "all"):
        serving_ab(generations=min(args.generations, 3))
    if args.suite in ("tensor_evo", "all"):
        tensor_evo_ab()
    if args.suite in ("analysis", "all"):
        analysis_ab(generations=max(args.generations, 12))
    if args.suite in ("surrogate", "all"):
        surrogate_ab(generations=max(args.generations, 10))
    if args.suite in ("liveloop", "all"):
        liveloop_ab()
    if args.suite in ("sharded_serving", "all"):
        sharded_serving_ab(generations=max(args.generations, 4))


if __name__ == "__main__":
    main()

"""Render the §Dry-run / §Roofline markdown tables from the recorded cell
jsons.  Usage:  PYTHONPATH=src python -m benchmarks.report [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json


DEFAULT_DIR = ("experiments/dryrun_final"
               if glob.glob("experiments/dryrun_final/*.json")
               else "experiments/dryrun")


def rows(mesh: str, d: str = None):
    out = []
    for f in sorted(glob.glob(f"{d or DEFAULT_DIR}/*.json")):
        r = json.load(open(f))
        if r.get("mesh") != ("2x16x16" if mesh == "multi" else "16x16"):
            continue
        out.append(r)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    rs = rows(args.mesh)
    print("| arch | shape | status | compile s | temp GB/dev | compute s | "
          "memory s | collective s | dominant | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rs:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | | |")
            continue
        rl = r["roofline"]
        temp = r["memory"].get("temp_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} | "
              f"{temp:.1f} | {rl['compute_s']:.3g} | {rl['memory_s']:.3g} | "
              f"{rl['collective_s']:.3g} | {rl['dominant']} | "
              f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.4f} |")


if __name__ == "__main__":
    main()

"""Render markdown tables from recorded experiment jsons.

Two modes:

* default — the §Dry-run / §Roofline table from the recorded cell jsons
  (``experiments/dryrun*``):  PYTHONPATH=src python -m benchmarks.report
  [--mesh single]
* ``--experiments`` — aggregate ``experiments/perf/*.json`` (the
  ``benchmarks.perf_ab`` outputs) into the tables EXPERIMENTS.md quotes:
  per-cell §Perf iteration logs (cell, iterations, best step, speedup) and
  the A/B-suite headline numbers — so the headline figures are regenerable
  instead of hand-copied:
  PYTHONPATH=src python -m benchmarks.report --experiments
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re


DEFAULT_DIR = ("experiments/dryrun_final"
               if glob.glob("experiments/dryrun_final/*.json")
               else "experiments/dryrun")
PERF_DIR = "experiments/perf"

# perf-cell record names look like <cell>_<step-index>_<description>.json
_CELL_RE = re.compile(r"^(?P<cell>.+)_(?P<step>\d+)_(?P<desc>.+)$")


def rows(mesh: str, d: str = None):
    out = []
    for f in sorted(glob.glob(f"{d or DEFAULT_DIR}/*.json")):
        r = json.load(open(f))
        if r.get("mesh") != ("2x16x16" if mesh == "multi" else "16x16"):
            continue
        out.append(r)
    return out


def dryrun_report(mesh: str, d: str = None) -> None:
    rs = rows(mesh, d)
    print("| arch | shape | status | compile s | temp GB/dev | compute s | "
          "memory s | collective s | dominant | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rs:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | | |")
            continue
        rl = r["roofline"]
        temp = r["memory"].get("temp_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} | "
              f"{temp:.1f} | {rl['compute_s']:.3g} | {rl['memory_s']:.3g} | "
              f"{rl['collective_s']:.3g} | {rl['dominant']} | "
              f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.4f} |")


# -- --experiments: aggregate experiments/perf/*.json ----------------------

def _perf_cells(d: str) -> dict[str, list[dict]]:
    """Group <cell>_<n>_<desc>.json records by cell, ordered by step."""
    cells: dict[str, list[dict]] = {}
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        name = os.path.splitext(os.path.basename(f))[0]
        m = _CELL_RE.match(name)
        if not m:
            continue
        rec = json.load(open(f))
        rec["_step"] = int(m.group("step"))
        rec["_desc"] = m.group("desc")
        cells.setdefault(m.group("cell"), []).append(rec)
    for recs in cells.values():
        recs.sort(key=lambda r: r["_step"])
    return cells


def perf_cell_table(d: str = PERF_DIR) -> None:
    """§Perf iteration log: per cell, the baseline-to-best progression."""
    cells = _perf_cells(d)
    if not cells:
        print(f"(no <cell>_<n>_<desc>.json records under {d}; run "
              "`python -m benchmarks.perf_ab` first)")
        return
    print("| cell | iterations | baseline step s | best step s | "
          "best iteration | speedup |")
    print("|---|---|---|---|---|---|")
    for cell, recs in sorted(cells.items()):
        ok = [r for r in recs if r.get("status") == "ok"]
        if not ok or recs[0].get("status") != "ok":
            # no usable records, or the true step-0 baseline failed — a
            # speedup against a later step would silently misreport
            best = (f"{min(ok, key=lambda r: r['roofline']['step_s'])['_step']}"
                    if ok else "")
            print(f"| {cell} | {len(recs)} | FAIL | | {best} | |")
            continue
        base = recs[0]["roofline"]["step_s"]
        best = min(ok, key=lambda r: r["roofline"]["step_s"])
        bs = best["roofline"]["step_s"]
        print(f"| {cell} | {len(recs)} | {base:.3f} | {bs:.3f} | "
              f"{best['_step']}: {best['_desc']} | {base / bs:.2f}x |")


def suite_headlines(d: str = PERF_DIR) -> None:
    """The A/B-suite headline numbers EXPERIMENTS.md quotes."""
    print("\n| suite | headline |")
    print("|---|---|")

    def load(name):
        p = os.path.join(d, name)
        return json.load(open(p)) if os.path.exists(p) else None

    ev = load("evaluator_ab.json")
    if ev:
        print(f"| evaluator | parallel x{ev['workers']} = "
              f"{ev['speedup_parallel_vs_serial']}x vs serial; warm-cache "
              f"rerun = {ev['parallel_warm_cache']['n_evals']} re-evals |")
    op = load("operators_ab.json")
    if op:
        print(f"| operators | five-op mix = "
              f"{op['hv_ratio_full_vs_legacy']}x hypervolume vs legacy; "
              f"best error {op['full']['best_error']:.3f} vs "
              f"{op['legacy']['best_error']:.3f} |")
    kn = load("kernels_ab.json")
    if kn:
        parts = [f"{k}: {r['evolved_vs_default']}x vs default"
                 for k, r in kn["kernels"].items()]
        print(f"| kernels | evolved schedules: {'; '.join(parts)} |")
    isl = load("islands_ab.json")
    if isl:
        print(f"| islands | 4 heterogeneous islands = "
              f"{isl['hv_ratio_islands_vs_single']}x hypervolume vs 1 "
              f"island at >= equal unique-genome budget "
              f"({isl['islands']['unique_genomes']} genomes, "
              f"{isl['islands']['cross_island_hits']} cross-island cache "
              f"hits) |")
    sv = load("serving_ab.json")
    if sv:
        g = sv["evolved"]["schedule"]
        print(f"| serving | evolved serving artifact "
              f"(max_slots={g['max_slots']}, "
              f"prefill_chunk={g['prefill_chunk']}) = "
              f"{sv['throughput_ratio_evolved_vs_default']}x throughput vs "
              f"the default schedule "
              f"({sv['evolved']['throughput_tok_s']:.0f} vs "
              f"{sv['default']['throughput_tok_s']:.0f} tok/s; "
              f"{sv['serve_cache_records']} serve-tagged cache records) |")
    tv = load("tensor_evo_ab.json")
    if tv:
        print(f"| tensor_evo | tensorized engine = "
              f"{tv['speedup_tensor_vs_python']}x population-evals/sec vs "
              f"the Python engine (pop {tv['tensor']['pop_size']}); mesh "
              f"islands vs panmictic = "
              f"{tv['hv_ratio_islands_vs_panmictic']}x hypervolume at "
              f"{tv['islands']['genome_evals']} genome-evals "
              f"({tv['budget_ratio_vs_pr4']}x the PR-4 budget, "
              f"{tv['islands']['cross_island_hits']} cross-island cache "
              f"hits) |")
    an = load("analysis_ab.json")
    if an:
        per = "; ".join(
            f"{k}: {r['skip_rate']:.0%}"
            for k, r in an["searches"].items())
        print(f"| analysis | static screen: fronts byte-identical screened "
              f"vs unscreened at equal genome budget; "
              f"{an['skip_rate_overall']:.0%} of cache-missing mutants "
              f"resolved without execution ({per}) |")
    sur = load("surrogate_ab.json")
    if sur:
        st = sur["guided"]["surrogate"]
        print(f"| surrogate | surrogate-guided search = "
              f"{sur['hv_ratio_guided_vs_unguided']}x hypervolume vs "
              f"unguided at "
              f"{sur['executed_frac_guided_vs_unguided']:.0%} of the "
              f"executed evaluations, equal genome budget (kept "
              f"{st['kept']}/{st['ranked']} ranked offspring over "
              f"{st['refits']} refits) |")
    ll = load("liveloop_ab.json")
    if ll:
        g = ll["promote"]["promoted_genome"]
        rb = ll["rollback"]
        print(f"| liveloop | live loop promoted "
              f"(max_slots={g['max_slots']}, "
              f"prefill_chunk={g['prefill_chunk']}) = "
              f"{ll['promote']['throughput_ratio_promoted_vs_default']}x "
              f"throughput vs the default schedule "
              f"({ll['promote']['promoted_tok_s']['median']:.0f} vs "
              f"{ll['promote']['default_tok_s']['median']:.0f} tok/s, "
              f"{ll['ticks']} ticks); fault-injected arm rolled back and "
              f"blocked {len(rb['blocked'])} fingerprint(s) |")
    sh = load("sharded_serving_ab.json")
    if sh:
        g = sh["search"]["selected_genome"]
        print(f"| sharded_serving | evolved serve plan "
              f"(max_slots={g['max_slots']}, "
              f"kv={g['kv_dtype']}/p{g['kv_page_size']}, "
              f"replicas={g['replicas']}) = "
              f"{sh['throughput_ratio_evolved_vs_default']}x throughput vs "
              f"the default plan on a smoke mesh "
              f"({sh['evolved']['throughput_tok_s']:.0f} vs "
              f"{sh['default']['throughput_tok_s']:.0f} tok/s); router = "
              f"{sh['throughput_ratio_router_vs_single']}x a single "
              f"replica of the same plan |")
    if not any((ev, op, kn, isl, sv, tv, an, sur, ll, sh)):
        print(f"| (none) | no *_ab.json suite records under {d} |")


def analysis_screen_table(d: str = PERF_DIR) -> None:
    """§Static triage: per-operator proposed/applied + screen-verdict
    counts from the screened ``analysis_ab`` arms."""
    p = os.path.join(d, "analysis_ab.json")
    if not os.path.exists(p):
        return
    an = json.load(open(p))
    print("\n| search | operator | proposed | applied | invalid | noop | "
          "equivalent |")
    print("|---|---|---|---|---|---|---|")
    for name, rec in an["searches"].items():
        for op_name, row in sorted(
                rec["screened"]["per_operator"].items()):
            print(f"| {name} | {op_name} | {row['proposed']} | "
                  f"{row['applied']} | {row.get('invalid', 0)} | "
                  f"{row.get('noop', 0)} | {row.get('equivalent', 0)} |")
    print("\nScreen-verdict counts are per *edit*, like the valid/elite "
          "counters: a screened patch contributes one count per edit it "
          "carries.  Since patches inherit their parents' edits, a kind's "
          "screen counts can exceed its own proposal count.")


def surrogate_rank_table(d: str = PERF_DIR) -> None:
    """§Surrogate pre-rank: per-operator ranked/kept survival counts from
    the guided ``surrogate_ab`` arm."""
    p = os.path.join(d, "surrogate_ab.json")
    if not os.path.exists(p):
        return
    sur = json.load(open(p))
    print("\n| operator | proposed | ranked | kept | survival |")
    print("|---|---|---|---|---|")
    for op_name, row in sorted(sur["guided"]["per_operator"].items()):
        ranked, kept = row.get("ranked", 0), row.get("kept", 0)
        rate = f"{kept / ranked:.0%}" if ranked else ""
        print(f"| {op_name} | {row['proposed']} | {ranked} | {kept} | "
              f"{rate} |")
    print("\nRanked/kept counts are per *edit*, like the screen-verdict "
          "counters, and only cover offspring the model actually ranked — "
          "cache hits and un-featurizable patches bypass the pre-rank.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--experiments", action="store_true",
                    help="aggregate experiments/perf/*.json into the "
                         "EXPERIMENTS.md tables instead of the dry-run "
                         "report")
    ap.add_argument("--dir", default=None,
                    help="records directory (default per mode)")
    args = ap.parse_args()
    if args.experiments:
        perf_cell_table(args.dir or PERF_DIR)
        suite_headlines(args.dir or PERF_DIR)
        analysis_screen_table(args.dir or PERF_DIR)
        surrogate_rank_table(args.dir or PERF_DIR)
    else:
        dryrun_report(args.mesh, args.dir)


if __name__ == "__main__":
    main()

"""GEVO over the Pallas kernel layer: evolve a kernel's schedule.

The schedule genome (implementation choice, block sizes, epilogue fusion) is
encoded as an HLO-lite program of knob constants, mutated through the
registered ``attr_tweak`` operator, and searched with the same NSGA-II +
cached-evaluator engine as IR-level GEVO-ML — fitness is
``argmin(schedule-aware roofline time, max |out - ref|)``, with every
candidate schedule actually executed against the kernel's jnp oracle.  Run:

    PYTHONPATH=src python examples/gevo_kernels.py --kernel rmsnorm

Flags:

    --kernel NAME       rmsnorm | flash_attention | mamba_scan
    --time-mode MODE    static (deterministic roofline, default) | measured
                        (median wall-clock of the jitted variant)
    --minimize          ddmin the best-by-time patch to its key tweaks
    --artifacts DIR     export the winner to an ArtifactRegistry (serving
                        paths pick it up via resolve_kernel_schedule)
    --surrogate         cache-trained cost model pre-ranks offspring; only
                        the predicted-Pareto slice is executed
    --surrogate-keep F  fraction of generated offspring that slice keeps
    --parallel N / --cache PATH / --generations G   as in quickstart.py
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import minimize_patch
from repro.core.evaluator import make_evaluator
from repro.kernels.workloads import (KERNELS, SHAPES, build_kernel_workload,
                                     evolve_kernel_schedule)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="rmsnorm", choices=KERNELS)
    ap.add_argument("--time-mode", default="static",
                    choices=("static", "measured"))
    ap.add_argument("--generations", type=int, default=5)
    ap.add_argument("--pop", type=int, default=8)
    ap.add_argument("--minimize", action="store_true",
                    help="minimize the best-by-time patch to its key tweaks")
    ap.add_argument("--parallel", type=int, default=0,
                    help="evaluation worker processes (0/1 = in-process)")
    ap.add_argument("--cache", default=None,
                    help="persistent fitness cache path (JSONL)")
    ap.add_argument("--artifacts", default=None,
                    help="export the winning schedule to this "
                         "ArtifactRegistry directory (resolved by serving "
                         "paths via resolve_kernel_schedule)")
    ap.add_argument("--surrogate", action="store_true",
                    help="surrogate pre-rank: a cache-trained cost model "
                         "keeps only the predicted-Pareto slice of each "
                         "generation's offspring for execution")
    ap.add_argument("--surrogate-keep", type=float, default=0.5,
                    help="fraction of generated offspring the surrogate "
                         "lets through (default 0.5)")
    args = ap.parse_args()

    print(f"Building {args.kernel} schedule workload "
          f"({SHAPES[args.kernel]}, {args.time_mode} time)...")
    w = build_kernel_workload(args.kernel, time_mode=args.time_mode)
    print(f"  schedule space: {w.space.size()} configs over "
          f"{{{', '.join(w.space.names())}}}")
    t0, e0 = w.evaluate(w.program)
    print(f"  default schedule [{w.space.describe(w.program)}]: "
          f"time={t0:.3e}s  err={e0:.2e}\n")

    print(f"Evolving schedules (NSGA-II, pop={args.pop}, "
          f"{args.generations} generations, operator=attr_tweak)...")
    evaluator = make_evaluator(w, parallel=args.parallel,
                               cache_path=args.cache,
                               features=args.surrogate)
    search, res, best, within_tol = evolve_kernel_schedule(
        w, generations=args.generations, pop_size=args.pop, seed=0,
        evaluator=evaluator, verbose=True, surrogate=args.surrogate,
        surrogate_keep=args.surrogate_keep)

    # compare against the baseline sample the search itself used (in
    # measured mode the preamble's t0 is an independent measurement)
    t0, _ = res.original_fitness
    print("\nPareto front (argmin(time, error)):")
    for ind in res.pareto:
        t, e = ind.fitness
        genome = w.space.decode(ind.patch.apply(w.program))
        mark = f"  time -{(1 - t / t0) * 100:.1f}%" if t < t0 * 0.999 else ""
        print(f"  time={t:.3e}  err={e:.2e}{mark}")
        print(f"    schedule: {', '.join(f'{k}={v}' for k, v in genome.items())}")
    gate = "" if within_tol else "  (no schedule met the error gate!)"
    print(f"\nbest-by-time schedule beats default by "
          f"{(1 - best.fitness[0] / t0) * 100:.1f}%{gate} "
          f"({search.n_evals} evaluations, "
          f"cache hit rate {search.cache.hit_rate:.0%})")
    if args.surrogate:
        st = search.guide.stats()
        print(f"surrogate pre-rank: kept {st['kept']}/{st['ranked']} "
              f"ranked offspring across {st['refits']} refits")
    if args.minimize:
        small, fit = minimize_patch(best.patch, search.evaluator,
                                    expect_fitness=best.fitness)
        print(f"minimized best-by-time patch: {len(best.patch)} -> "
              f"{len(small)} edits at identical fitness; "
              f"key tweaks: {small.describe()}")
    if args.artifacts:
        from repro.core.deploy import ArtifactRegistry
        from repro.kernels.workloads import kernel_artifact
        genome = w.space.decode(best.patch.apply(w.program))
        path = ArtifactRegistry(args.artifacts).export(kernel_artifact(
            args.kernel, genome, fitness=best.fitness,
            meta={"time_mode": args.time_mode, "within_tol": within_tol,
                  "rule": "min time s.t. error <= default + 1e-3"}))
        print(f"exported winning schedule to {path}")
    evaluator.close()


if __name__ == "__main__":
    main()

"""Continuous-batching serving demo across architecture families.

For each decoder arch (reduced config), replays a staggered mixed-length
request trace through the :class:`~repro.core.deploy.ServeEngine` —
micro-batched prefill interleaved with vmapped per-lane decode over KV /
compressed-MLA / SSM caches — and prints the measured throughput and
latency, plus a correctness check of the continuous path against the
one-shot oracle.

    PYTHONPATH=src python examples/serve_batch.py
    PYTHONPATH=src python examples/serve_batch.py --arch qwen3-0.6b \
        --artifacts experiments/artifacts
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_ARCHS = ("qwen3-0.6b", "deepseek-v3-671b", "falcon-mamba-7b",
                 "zamba2-1.2b")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable; default: one per family)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--stagger", type=int, default=2)
    ap.add_argument("--artifacts", default=None,
                    help="resolve the serving schedule from this "
                         "ArtifactRegistry instead of the default")
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.core.deploy import (ArtifactRegistry, ServeEngine,
                                   engine_schedule_from, oneshot_generate)
    from repro.core.liveloop.traces import demo_requests

    registry = ArtifactRegistry(args.artifacts) if args.artifacts else None
    for arch in (args.arch or DEFAULT_ARCHS):
        cfg = smoke_config(arch)
        art = (registry.resolve(cfg.name, "smoke", kind="serve")
               if registry else None)
        schedule = engine_schedule_from(art)
        print(f"=== {arch} ({cfg.family}, reduced config, "
              f"schedule={schedule}"
              f"{' from ' + args.artifacts if art else ''}) ===", flush=True)
        engine = ServeEngine(cfg, max_len=args.prompt_len + args.gen,
                             max_slots=schedule["max_slots"],
                             prefill_chunk=schedule["prefill_chunk"])
        trace = demo_requests(cfg, n_requests=args.requests,
                              prompt_len=args.prompt_len, gen=args.gen)
        results = engine.run(trace, stagger=args.stagger or None)
        s = engine.stats()
        rec = s["per_variant"]["default"]
        print(f"  {len(results)} requests in {s['wall_s']:.2f}s "
              f"({s['throughput_tok_s']:.1f} tok/s, "
              f"ttft {rec['mean_ttft_s'] * 1e3:.0f}ms, "
              f"latency {rec['mean_latency_s'] * 1e3:.0f}ms, "
              f"{s['decode_batches']} decode dispatches)")
        # continuous batching must reproduce the one-shot oracle exactly
        probe = trace[0]
        ref = oneshot_generate(cfg, engine.params, probe.tokens[None, :],
                               probe.max_new_tokens)[0].tolist()
        got = next(r.tokens for r in results if r.uid == probe.uid)
        assert got == ref, f"{arch}: engine diverged from one-shot oracle"
        print(f"  {probe.uid}: {got[:10]}... (matches one-shot oracle)")


if __name__ == "__main__":
    main()

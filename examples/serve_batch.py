"""Batched serving demo across architecture families: prefill a batch of
prompts and decode continuations with KV / compressed-MLA / SSM caches.

    PYTHONPATH=src python examples/serve_batch.py
"""

import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main():
    for arch in ("qwen3-0.6b", "deepseek-v3-671b", "falcon-mamba-7b",
                 "zamba2-1.2b"):
        print(f"=== {arch} (reduced config) ===", flush=True)
        subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--smoke", "--batch", "4", "--prompt-len", "24", "--gen", "8"],
            env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
            cwd=ROOT, check=True)


if __name__ == "__main__":
    main()

"""Island-model GEVO: N populations, migration, one shared fitness cache.

Runs :class:`repro.core.IslandOrchestrator` over any of the engine's
scenario families — the paper's IR-level workloads (2fcNet training,
MobileNet prediction) or the kernel-schedule spaces (rmsnorm,
flash_attention, mamba_scan).  Each island gets its own RNG stream,
operator mix, and mutation rate (heterogeneous palette by default); elites
migrate every K generations over a configurable topology; all islands share
one concurrency-safe fitness cache, so a migrant is never re-evaluated by
its destination.  Run:

    PYTHONPATH=src python examples/gevo_islands.py --workload twofc \
        --islands 2 --generations 2          # CI smoke budget
    PYTHONPATH=src python examples/gevo_islands.py --workload rmsnorm \
        --islands 4 --generations 6 --topology broadcast_best

Flags:

    --workload NAME     twofc | mobilenet | rmsnorm | flash_attention |
                        mamba_scan | joint (all three kernels, one genome)
    --engine E          python (spawned-process islands, default) | tensor
                        (device-mesh islands: the whole fleet steps as one
                        vmapped array program; kernel workloads only)
    --islands N         number of islands (default 4)
    --migrate-every K   generations between migrations (default 2)
    --migrants M        NSGA-II-best individuals each source sends (2)
    --topology T        ring | full | broadcast_best (default ring)
    --processes MODE    auto | on | off — island worker processes; "auto"
                        consults repro.core.islands.plan() (default off)
    --root DIR          state directory (manifest + island checkpoints +
                        shared cache); enables --resume.  Default: temp dir
    --resume            continue a killed run from --root (bit-exact)
    --surrogate         cache-trained cost model pre-ranks offspring on
                        every island (the shared cache trains all models)
    --surrogate-keep F  fraction of generated offspring that is executed
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import IslandOrchestrator, default_island_specs
from repro.core.islands import TOPOLOGIES, plan

WORKLOADS = ("twofc", "mobilenet", "rmsnorm", "flash_attention",
             "mamba_scan", "joint")
KERNELS = ("rmsnorm", "flash_attention", "mamba_scan", "joint")


def build_workload(name: str):
    """(workload, operators) for the orchestrator: IR workloads use the
    heterogeneous operator palette, schedule spaces pin attr_tweak."""
    if name == "twofc":
        from repro.workloads.twofc import build_twofc_training_workload
        return build_twofc_training_workload(
            batch=32, hidden=64, steps=60, n_train=2048, n_test=1024), None
    if name == "mobilenet":
        from repro.workloads.mobilenet import \
            build_mobilenet_prediction_workload
        print("Pretraining MobileNet on synthetic CIFAR10...")
        return build_mobilenet_prediction_workload(
            alpha=0.125, n_eval=512, n_pretrain=2000, pretrain_epochs=2,
            verbose=True), None
    if name == "joint":
        from repro.kernels.workloads import build_joint_kernel_workload
        return build_joint_kernel_workload(), {"attr_tweak": 1.0}
    from repro.kernels.workloads import build_kernel_workload
    return (build_kernel_workload(name, time_mode="static"),
            {"attr_tweak": 1.0})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="twofc", choices=WORKLOADS)
    ap.add_argument("--engine", default="python",
                    choices=("python", "tensor"),
                    help="tensor = device-mesh island fleet (kernel "
                         "workloads only; see DESIGN.md Tensorized search)")
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--generations", type=int, default=6)
    ap.add_argument("--pop", type=int, default=8,
                    help="population size per island")
    ap.add_argument("--migrate-every", type=int, default=2)
    ap.add_argument("--migrants", type=int, default=2)
    ap.add_argument("--topology", default="ring", choices=TOPOLOGIES)
    ap.add_argument("--processes", default="off",
                    choices=("auto", "on", "off"))
    ap.add_argument("--root", default=None,
                    help="state directory (default: fresh temp dir)")
    ap.add_argument("--export-front", default=None, metavar="PATH",
                    help="write the merged Pareto front as a deployable "
                         "front doc (ParetoFront.load / the deploy CLI)")
    ap.add_argument("--resume", action="store_true",
                    help="continue a killed run from --root")
    ap.add_argument("--surrogate", action="store_true",
                    help="surrogate pre-rank on every island: a cost model "
                         "trained from the shared fitness cache keeps only "
                         "the predicted-Pareto slice of each generation's "
                         "offspring")
    ap.add_argument("--surrogate-keep", type=float, default=0.5,
                    help="fraction of generated offspring the surrogate "
                         "lets through (default 0.5)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.resume and not args.root:
        ap.error("--resume requires --root")
    if args.surrogate and args.engine == "tensor":
        ap.error("--surrogate drives the python island engine; for the "
                 "tensor engine use TensorGevoML(surrogate=True) directly")
    if args.engine == "tensor" and args.workload not in KERNELS:
        ap.error("--engine tensor needs a kernel-schedule workload "
                 f"({', '.join(KERNELS)})")

    print(f"Building {args.workload} workload...")
    w, operators = build_workload(args.workload)
    t0, e0 = w.evaluate(w.program)
    print(f"  original fitness: time={t0:.3e}s  error={e0:.4f}")

    if args.engine == "tensor":
        processes, eval_workers = False, 0
        print("  engine: tensor (vmapped mesh fleet, no island processes)")
    elif args.processes == "auto":
        p = plan(args.islands)
        processes, eval_workers = p.processes, p.eval_workers
        print(f"  core plan: {p.describe()}")
    else:
        processes, eval_workers = args.processes == "on", 0
    if processes and getattr(w, "spec", None) is None:
        print("  (workload has no WorkloadSpec; falling back to "
              "in-process islands)")
        processes = False

    specs = default_island_specs(args.islands, operators=operators,
                                 base_seed=args.seed)
    root = args.root or tempfile.mkdtemp(prefix="gevo_islands_")
    print(f"\n{args.islands} islands (pop {args.pop} each), "
          f"{args.generations} generations, migrate every "
          f"{args.migrate_every} ({args.topology}, {args.migrants} "
          f"migrants), state in {root}")
    for s in specs:
        ops = s.operators if isinstance(s.operators, str) else \
            ",".join((s.operators or {"all": 1}).keys())
        print(f"  {s.name}: operators={ops} mut={s.mutation_rate} "
              f"seed={s.seed}")

    orch = IslandOrchestrator(
        w, root_dir=root, specs=specs, pop_size=args.pop,
        migrate_every=args.migrate_every, n_migrants=args.migrants,
        topology=args.topology, processes=processes,
        eval_workers=eval_workers, verbose=True,
        backend="mesh" if args.engine == "tensor" else "processes",
        surrogate=args.surrogate, surrogate_keep=args.surrogate_keep)
    res = orch.run(generations=args.generations, resume=args.resume)

    print("\nMerged Pareto front (argmin(time, error)):")
    for ind, src in zip(res.pareto, res.pareto_sources):
        t, e = ind.fitness
        mark = f"  time -{(1 - t / t0) * 100:.1f}%" if t < t0 * 0.999 else ""
        print(f"  time={t:.3e}  err={e:.4f}  [{src}]{mark}")
    moved = sum(len(v) for r in res.migration_log
                for v in r["migrants"].values())
    cs = res.cache_stats
    print(f"\n{len(res.migration_log)} migration rounds ({moved} migrants "
          f"moved); shared cache: {cs['entries']} unique genomes, "
          f"{res.cross_island_hits} cross-island hits")
    for name, isl in zip(res.names, res.islands):
        bt = min(i.fitness[0] for i in isl.pareto)
        be = min(i.fitness[1] for i in isl.pareto)
        ev = cs["per_island"].get(name, {})
        print(f"  {name}: best time={bt:.3e} best err={be:.4f} "
              f"evals={ev.get('n_evals', '?')} "
              f"cross_hits={ev.get('cross_hits', '?')}")
    if args.export_front:
        res.export_front(args.export_front, origin=root)
        print(f"\nexported merged front to {args.export_front} "
              f"(query it: python -m repro.core.deploy select "
              f"--front {args.export_front} --within 0.02)")
    print(f"\nresume any time with: --root {root} --resume")


if __name__ == "__main__":
    main()

"""End-to-end training driver: a ~100M-parameter qwen3-family model trained
for a few hundred steps on the synthetic token stream, with periodic async
checkpoints and automatic resume.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

On this single-CPU container expect ~5-10 s/step (the same script on a TPU
slice just needs --mesh and jax.distributed init via repro.launch.train).
Loss should fall from ~ln(32000)=10.4 toward ~4-6 as the model learns the
order-2 Markov structure of the stream.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models.transformer import init_params
from repro.optim.optimizers import adamw
from repro.optim.schedules import wsd_schedule
from repro.train.checkpoint import load_latest, restore_like, save_checkpoint
from repro.train.train_step import TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # qwen3 family scaled to ~100M params
    cfg = get_config("qwen3-0.6b").scaled(
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
        d_ff=1792, vocab=32000, dtype="float32", loss_chunk=0)
    print(f"model: {cfg.param_count()/1e6:.0f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    opt = adamw(lr=wsd_schedule(3e-4, args.steps // 10,
                                args.steps * 7 // 10, args.steps // 5))
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(params, opt.init(params))
    start = 0
    found = load_latest(args.ckpt)
    if found:
        start, flat = found
        state = restore_like(state, flat)
        print(f"resumed from step {start}")

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    t0 = time.time()
    pending = None
    for step in range(start, args.steps):
        state, m = step_fn(state, pipe.batch_at(step))
        if (step + 1) % 10 == 0 or step == start:
            print(f"step {step+1:4d}  loss={float(m['loss']):.4f}  "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)",
                  flush=True)
        if (step + 1) % 50 == 0:
            if pending:
                pending.join()
            pending = save_checkpoint(args.ckpt, state, step + 1,
                                      async_save=True)
    if pending:
        pending.join()
    save_checkpoint(args.ckpt, state, args.steps)
    print(f"finished {args.steps - start} steps "
          f"in {time.time()-t0:.0f}s; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()

"""The paper's prediction experiment: GEVO-ML on MobileNet/CIFAR10-syn
(Figure 4a).  Pretrains MobileNet in JAX, bakes it into the IR with weights
as constants, then evolves registry-operator patches (``--operators``
selects the mix; default all five) minimizing
(inference time, prediction error).

    PYTHONPATH=src python examples/gevo_mobilenet.py [--full]

The paper's headline: 90.43% runtime improvement at a 2% test-accuracy
cost.  At example scale (reduced width/eval set/generations) expect smaller
but clearly visible Pareto spread in the same direction.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import GevoML, OperatorWeights
from repro.core.evaluator import make_evaluator
from repro.workloads.mobilenet import build_mobilenet_prediction_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger model / eval set / budget (slow)")
    ap.add_argument("--operators", default="all",
                    help='mutation mix: "all", "legacy", or '
                         '"name=w,name=w,..."')
    ap.add_argument("--parallel", type=int, default=0,
                    help="evaluation worker processes (0/1 = in-process); "
                         "the pretrained workload ships to workers whole")
    ap.add_argument("--cache", default=None,
                    help="persistent fitness cache path (JSONL)")
    args = ap.parse_args()

    t0 = time.time()
    print("Pretraining MobileNet on synthetic CIFAR10...")
    w = build_mobilenet_prediction_workload(
        alpha=0.25 if args.full else 0.125,
        n_eval=2048 if args.full else 512,
        n_pretrain=6000 if args.full else 2000,
        pretrain_epochs=4 if args.full else 2, verbose=True)
    tt, ee = w.evaluate(w.program)
    print(f"  baked IR: {len(w.program.ops)} ops; original time={tt:.3e}s "
          f"err={ee:.4f}  [{time.time()-t0:.0f}s]")

    evaluator = make_evaluator(w, parallel=args.parallel,
                               cache_path=args.cache)
    s = GevoML(w, pop_size=12 if args.full else 8,
               n_elite=6 if args.full else 4, seed=0, verbose=True,
               operators=OperatorWeights.parse(args.operators),
               evaluator=evaluator)
    res = s.run(generations=6 if args.full else 3)
    evaluator.close()

    print("\nPareto front:")
    t0_, e0 = res.original_fitness
    for ind in res.pareto:
        t, e = ind.fitness
        print(f"  time={t:.3e} ({(1-t/t0_)*100:+5.1f}%)  err={e:.4f} "
              f"({(e-e0)*100:+.2f}pp)")
        print(f"    {ind.patch.describe()}")
    ok = [i for i in res.pareto if i.fitness[1] <= e0 + 0.02]
    if ok:
        fastest = min(ok, key=lambda i: i.fitness[0])
        print(f"\npaper-style headline: {(1-fastest.fitness[0]/t0_)*100:.1f}% "
              f"runtime improvement at <=2% accuracy cost")


if __name__ == "__main__":
    main()

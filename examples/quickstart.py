"""Quickstart: GEVO-ML in miniature (~2 minutes on CPU).

Reproduces the paper's training experiment structure on 2fcNet/MNIST-syn:
NSGA-II evolves patches of the training-step IR — sampled from the pluggable
operator registry (delete / copy / swap / insert / const_perturb) — and the
Pareto front trades runtime against model error.  Run:

    PYTHONPATH=src python examples/quickstart.py

Edit-layer flags (see README "Operator registry"):

    --operators SPEC    sampling mix: "all" (default), "legacy"
                        (paper's copy/delete), or "copy=1,swap=2,..."
    --minimize          ddmin the best-by-time patch down to its key
                        mutations (nearly free: reuses the fitness cache)

Evaluation-engine flags (see README "Evaluation engine"):

    --parallel N        evaluate variants in N worker processes
    --cache PATH        persistent fitness cache (JSONL); rerun with the
                        same path and the search re-measures nothing
    --checkpoint DIR    write per-generation snapshots
    --resume            continue from the latest snapshot in --checkpoint
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import GevoML, OperatorWeights, minimize_patch
from repro.core.evaluator import make_evaluator
from repro.workloads.twofc import build_twofc_training_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--operators", default="all",
                    help='mutation mix: "all", "legacy", or '
                         '"name=w,name=w,..." over '
                         "{delete,copy,swap,insert,const_perturb}")
    ap.add_argument("--minimize", action="store_true",
                    help="minimize the best-by-time patch to its key "
                         "mutations (GEVO Sec. 6 style)")
    ap.add_argument("--parallel", type=int, default=0,
                    help="evaluation worker processes (0/1 = in-process)")
    ap.add_argument("--cache", default=None,
                    help="persistent fitness cache path (JSONL)")
    ap.add_argument("--checkpoint", default=None,
                    help="checkpoint directory (one snapshot per generation)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --checkpoint")
    ap.add_argument("--generations", type=int, default=5)
    args = ap.parse_args()
    if args.resume and not args.checkpoint:
        ap.error("--resume requires --checkpoint")
    weights = OperatorWeights.parse(args.operators)

    print("Building 2fcNet training workload (one SGD step as IR)...")
    w = build_twofc_training_workload(batch=32, hidden=64, steps=80,
                                      n_train=2048, n_test=1024, lr=0.01)
    print(f"  program: {len(w.program.ops)} HLO-lite ops, "
          f"{len(w.program.inputs)} inputs")
    t0, e0 = w.evaluate(w.program)
    print(f"  original fitness: time={t0:.3e}s  error={e0:.4f}\n")

    mode = (f"{args.parallel} workers" if args.parallel > 1 else "serial")
    print(f"Running GEVO-ML (NSGA-II, pop=12, {args.generations} "
          f"generations, operators={{{', '.join(weights.names())}}}, "
          f"{mode} evaluation)...")
    evaluator = make_evaluator(w, parallel=args.parallel,
                               cache_path=args.cache)
    search = GevoML(w, pop_size=12, n_elite=6, seed=0, verbose=True,
                    operators=weights, evaluator=evaluator,
                    checkpoint_dir=args.checkpoint)
    res = search.run(generations=args.generations, resume=args.resume)

    print("\nPareto front (argmin(time, error)):")
    for ind in res.pareto:
        t, e = ind.fitness
        marks = []
        if t < t0 * 0.999:
            marks.append(f"time -{(1-t/t0)*100:.1f}%")
        if e < e0 - 1e-4:
            marks.append(f"error -{(e0-e)*100:.2f}pp")
        print(f"  time={t:.3e}  err={e:.4f}  {' '.join(marks)}")
        print(f"    patch: {ind.patch.describe()}")
    be = res.best_by_error()
    print(f"\nbest error {be.fitness[1]:.4f} vs original {e0:.4f} "
          f"({search.n_evals} fitness evaluations, "
          f"{search.n_invalid} invalid variants resampled, "
          f"cache hit rate {search.cache.hit_rate:.0%})")
    print("per-operator proposed/applied/valid/elite:")
    for name, row in res.operator_stats().items():
        print(f"  {name:>14}: {row['proposed']:4d} / {row['applied']:4d} / "
              f"{row['valid']:4d} / {row['elite']:4d}")
    if args.minimize:
        bt = res.best_by_time()
        small, fit = minimize_patch(bt.patch, search.evaluator,
                                    expect_fitness=bt.fitness)
        print(f"\nminimized best-by-time patch: {len(bt.patch)} -> "
              f"{len(small)} edits at identical fitness {fit}")
        print(f"  key mutations: {small.describe()}")
    if args.cache:
        print(f"fitness cache: {len(search.cache)} entries at {args.cache}")
    evaluator.close()


if __name__ == "__main__":
    main()
